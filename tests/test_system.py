"""End-to-end behaviour: the paper's headline claims on the full system."""
import jax
import numpy as np

from repro.core import GAS, LMC, from_graph, full_grads
from repro.graph import ClusterSampler
from repro.models import make_gnn
from repro.optim import sgd
from repro.train import GNNTrainer


def _train(g, parts, method, steps=80, seed=0, lr=0.3):
    gnn = make_gnn("gcn", g.feature_dim, 64, g.num_classes, 2)
    s = ClusterSampler(g, 16, 2, parts=parts, seed=seed,
                       include_halo=method.include_halo,
                       edge_weight_mode=method.edge_weight_mode)
    tr = GNNTrainer(gnn, method, g, s, sgd(lr=lr), seed=seed)
    tr.run(steps)
    return tr


def test_lmc_trains_to_usable_accuracy(small_graph, small_parts):
    tr = _train(small_graph, small_parts, LMC, steps=120)
    acc = float(tr.eval("test"))
    assert acc > 0.5, acc  # 16-class ppi-like; chance is ~6%


def test_lmc_matches_or_beats_gas(small_graph, small_parts):
    """Tbl 2 / Fig 2 in miniature: at equal step budget LMC's final loss
    is within noise of, or better than, GAS's (averaged over seeds)."""
    lmc_best, gas_best = [], []
    for seed in (0, 1):
        lmc = _train(small_graph, small_parts, LMC, steps=100, seed=seed)
        gas = _train(small_graph, small_parts, GAS, steps=100, seed=seed)
        lmc_best.append(min(h["loss"] for h in lmc.history if "loss" in h))
        gas_best.append(min(h["loss"] for h in gas.history if "loss" in h))
    assert np.mean(lmc_best) <= np.mean(gas_best) * 1.05, \
        (lmc_best, gas_best)


def test_full_batch_gd_reference(small_graph):
    """Full-batch GD on the same model converges (sanity of the oracle)."""
    g = small_graph
    data = from_graph(g)
    gnn = make_gnn("gcn", g.feature_dim, 64, g.num_classes, 2)
    params = gnn.init_params(jax.random.key(0))

    @jax.jit
    def gd(p):
        loss, grads = full_grads(gnn, p, data)
        return loss, jax.tree.map(lambda w, d: w - 0.5 * d, p, grads)

    losses = []
    for _ in range(60):
        loss, params = gd(params)
        losses.append(float(loss))
    assert losses[-1] < 0.5 * losses[0]
