"""Smoke tests for the runnable examples.

Each example is a user's first contact with the repo, so each gets a
subprocess run at the smallest sensible scale: exit 0 and the headline
output lines present. These are end-to-end (fresh interpreter, real argv
parsing, real device work) — exactly the failure surface unit tests miss
when an example drifts out of sync with the library API.
"""
import os
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def _run(script, *args, timeout=300):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    res = subprocess.run(
        [sys.executable, str(REPO / "examples" / script), *args],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert res.returncode == 0, \
        f"{script} exited {res.returncode}:\n{res.stdout}\n{res.stderr}"
    return res.stdout


def test_quickstart_smoke():
    out = _run("quickstart.py", "--preset", "ppi-cpu", "--steps", "50")
    assert "=== cluster ===" in out
    assert "final test acc:" in out


def test_serve_decode_smoke():
    out = _run("serve_decode.py", "--arch", "zamba2-1.2b", "--batch", "2",
               "--prompt-len", "8", "--tokens", "4")
    assert "prefill 2x8" in out
    assert "decoded 4 tokens/seq" in out


def test_serve_gnn_smoke():
    out = _run("serve_gnn.py", "--requests", "8", "--qps", "50",
               "--train-steps", "20")
    assert "server up:" in out
    assert "'ok': 8" in out
    assert "drain clean: True" in out


def test_serve_gnn_fault_smoke():
    out = _run("serve_gnn.py", "--fault", "--requests", "24", "--qps", "80",
               "--train-steps", "20")
    assert "server events:" in out
    assert "drain clean: True" in out
    assert "pending after drain: 0" in out
