"""Optimizers: convergence sanity, state specs, 8-bit quantization bounds."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, strategies as st

from repro.models.spec import PSpec
from repro.optim import adafactor, adamw, adamw8bit, sgd, global_norm_clip
from repro.optim.optimizers import _q8_decode, _q8_encode


def _quadratic_target():
    a = jnp.asarray(np.random.default_rng(0).normal(size=(8, 8)), jnp.float32)
    A = a @ a.T + 0.5 * jnp.eye(8)
    b = jnp.ones((8,))

    def loss(p):
        return 0.5 * p["x"] @ A @ p["x"] - b @ p["x"]
    opt_x = jnp.linalg.solve(A, b)
    return loss, opt_x


@pytest.mark.parametrize("make_opt,lr,steps", [
    (sgd, 5e-2, 300), (adamw, 1e-1, 300), (adamw8bit, 1e-1, 300),
    (adafactor, 5e-2, 400),
])
def test_quadratic_convergence(make_opt, lr, steps):
    loss, opt_x = _quadratic_target()
    opt = make_opt(lr=lr)
    pspec = {"x": PSpec((8,), (None,), dtype=jnp.float32)}
    params = {"x": jnp.zeros((8,), jnp.float32)}
    state = opt.init(params, pspec)
    val = jax.jit(lambda p, s: opt.update(jax.grad(loss)(p), s, p, opt.lr))
    for _ in range(steps):
        params, state, _ = val(params, state)
    # wd in adamw biases the optimum; just require big progress toward it
    assert float(loss(params)) < 0.2 * float(loss({"x": jnp.zeros(8)}))


def test_state_specs_match_params():
    pspec = {"w": PSpec((16, 32), ("embed", "mlp")),
             "b": PSpec((32,), ("mlp",), init="zeros")}
    for opt in (sgd(), adamw(), adamw8bit(), adafactor()):
        st_abs = opt.abstract_state(pspec)
        assert jax.tree.leaves(st_abs), opt.name
    ada = adafactor().abstract_state(pspec)
    assert ada["vr"]["w"].shape == (16,)
    assert ada["vc"]["w"].shape == (32,)
    a8 = adamw8bit().abstract_state(pspec)
    assert a8["m_q"]["w"].dtype == jnp.int8


@given(seed=st.integers(0, 100), scale=st.floats(1e-6, 1e3))
@settings(max_examples=15)
def test_q8_roundtrip_error(seed, scale):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(7, 300)) * scale, jnp.float32)
    q, s = _q8_encode(x)
    back = _q8_decode(q, s, x.shape)
    # block-quantized to 1/127 of the block max
    blockmax = np.maximum.reduceat(np.abs(np.asarray(x)),
                                   np.arange(0, 300, 256), axis=1)
    tol = (blockmax.max() / 127) * 0.51 + 1e-9
    assert float(jnp.max(jnp.abs(back - x))) <= tol * 1.05


def test_global_norm_clip():
    g = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
    clipped, gn = global_norm_clip(g, 1.0)
    assert abs(float(gn) - np.sqrt(10 * 9 + 10 * 16)) < 1e-4
    total = np.sqrt(sum(float(jnp.sum(x ** 2))
                        for x in jax.tree.leaves(clipped)))
    assert abs(total - 1.0) < 1e-5


def test_spider_controller_estimates():
    """SPIDER running estimate tracks the true gradient on a quadratic."""
    from repro.optim import make_spider_controller
    loss, _ = _quadratic_target()
    init, should_anchor, anchor, refine = make_spider_controller(q=4)
    params = {"x": jnp.ones((8,), jnp.float32)}
    st = init(params)
    st = anchor(st, params, jax.grad(loss)(params))
    # move params; refine with same-batch grads at both points
    new_params = {"x": params["x"] * 0.9}
    st = refine(st, new_params, jax.grad(loss)(new_params),
                jax.grad(loss)(params))
    true_g = jax.grad(loss)(new_params)
    err = float(jnp.linalg.norm(st.g_est["x"] - true_g["x"]))
    assert err < 1e-5  # exact for deterministic quadratic
