"""HBM→VMEM streamed-gather coverage: the DMA double-buffered kernel paths
(fwd + custom-VJP bwd) must match the jnp oracles at gather-source sizes well
past the old ~24k-row resident-block VMEM cap, and streamed/resident must be
bit-compatible where both run. CPU CI exercises the exact DMA/semaphore
protocol through the Pallas interpreter; the compiled Mosaic lowering is
asserted by the TPU-gated compile check."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, strategies as st

from repro.kernels import (build_ell, bucketed_spmm, default_stream,
                           ell_spmm, lmc_compensate)
from repro.kernels.ref import (degree_bucket_spmm_ref, ell_spmm_ref,
                               lmc_compensate_ref)

# the resident block capped the gather source at ~24k f32 rows/device
# (12 MiB / 128 lanes / 4 bytes); streamed paths must clear 4x that
OLD_CAP_ROWS = 12 * 2**20 // (128 * 4)
BIG_M = 4 * OLD_CAP_ROWS + 1536


def _rect_csr(seed, n_rows, num_cols, max_deg=20):
    """Random rectangular CSR: n_rows rows gathering from num_cols sources."""
    r = np.random.default_rng(seed)
    deg = r.integers(0, max_deg, n_rows)
    indptr = np.zeros(n_rows + 1, np.int64)
    indptr[1:] = np.cumsum(deg)
    nnz = int(indptr[-1])
    indices = r.integers(0, num_cols, nnz).astype(np.int32)
    weights = r.random(nnz).astype(np.float32)
    return indptr, indices, weights


@given(seed=st.integers(0, 100), m_extra=st.sampled_from([0, 2560, 65536]))
@settings(max_examples=4)
def test_streamed_spmm_beyond_cap_fwd_and_grad(seed, m_extra):
    """bucketed_spmm (fwd + custom-VJP grad) vs the segment-sum oracle with a
    gather source ≥ 4x the old resident-block cap."""
    m = BIG_M + m_extra
    assert m >= 4 * OLD_CAP_ROWS
    n_rows = 150
    indptr, indices, ws = _rect_csr(seed, n_rows, m)
    g = build_ell(indptr, indices, ws, num_cols=m, block_rows=64)
    rng = np.random.default_rng(seed + 1)
    h = jnp.asarray(rng.normal(size=(m, 128)).astype(np.float32))
    ptr, ind, w = (jnp.asarray(indptr), jnp.asarray(indices), jnp.asarray(ws))

    f_k = lambda h_: jnp.sum(jnp.sin(bucketed_spmm(g, h_)))
    f_r = lambda h_: jnp.sum(jnp.sin(
        degree_bucket_spmm_ref(ptr, ind, w, h_)[:n_rows]))
    out = bucketed_spmm(g, h)
    ref = degree_bucket_spmm_ref(ptr, ind, w, h)[:n_rows]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    # the bwd SpMM streams over the bucketed Aᵀ whose *output* is full-graph
    # sized — the dh it produces covers all m source rows
    gk = jax.jit(jax.grad(f_k))(h)
    gr = jax.grad(f_r)(h)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(gr),
                               rtol=1e-5, atol=1e-5)


@given(seed=st.integers(0, 100), beta_max=st.floats(0.1, 1.0))
@settings(max_examples=4)
def test_streamed_compensate_beyond_cap_fwd_and_grad(seed, beta_max):
    """lmc_compensate (fwd + custom-VJP grads incl. the scatter-add store
    cotangent) vs the jnp oracle with a store ≥ 4x the old cap, at unaligned
    N/D (the ops wrapper pads to kernel tiles)."""
    m = BIG_M
    rng = np.random.default_rng(seed)
    n, d = 300, 50
    store = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    gids = jnp.asarray(rng.integers(0, m, n).astype(np.int32))
    beta = jnp.asarray((rng.random(n) * beta_max).astype(np.float32))
    mask = jnp.asarray((rng.random(n) > 0.2).astype(np.float32))
    fresh = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    out_k = lmc_compensate(store, gids, beta, fresh, mask)
    out_r = lmc_compensate_ref(store, gids, beta, fresh, mask)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-5, atol=1e-5)
    f_k = lambda s, b, f, mk: jnp.sum(jnp.cos(
        lmc_compensate(s, gids, b, f, mk)))
    f_r = lambda s, b, f, mk: jnp.sum(jnp.cos(
        lmc_compensate_ref(s, gids, b, f, mk)))
    gk = jax.jit(jax.grad(f_k, argnums=(0, 1, 2, 3)))(store, beta, fresh, mask)
    gr = jax.grad(f_r, argnums=(0, 1, 2, 3))(store, beta, fresh, mask)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


def test_stream_matches_resident_where_both_run():
    """At sizes the resident block still handles, streamed and resident paths
    agree exactly (same gather, different transport), fwd and grad."""
    indptr, indices, ws = _rect_csr(7, 120, 500)
    g = build_ell(indptr, indices, ws, num_cols=500, block_rows=64)
    rng = np.random.default_rng(0)
    h = jnp.asarray(rng.normal(size=(500, 64)).astype(np.float32))
    np.testing.assert_array_equal(
        np.asarray(bucketed_spmm(g, h, stream=True)),
        np.asarray(bucketed_spmm(g, h, stream=False)))
    gs = jax.grad(lambda h_: jnp.sum(
        jnp.sin(bucketed_spmm(g, h_, stream=True))))(h)
    gr = jax.grad(lambda h_: jnp.sum(
        jnp.sin(bucketed_spmm(g, h_, stream=False))))(h)
    np.testing.assert_array_equal(np.asarray(gs), np.asarray(gr))

    n, m, d = 200, 400, 128
    store = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    gids = jnp.asarray(rng.integers(0, m, n).astype(np.int32))
    beta = jnp.asarray(rng.random(n).astype(np.float32))
    mask = jnp.asarray((rng.random(n) > 0.3).astype(np.float32))
    fresh = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    np.testing.assert_array_equal(
        np.asarray(lmc_compensate(store, gids, beta, fresh, mask, stream=True)),
        np.asarray(lmc_compensate(store, gids, beta, fresh, mask,
                                  stream=False)))


def test_stream_default_is_streaming():
    """The autodetect default streams everywhere — and therefore the old
    trace-time VMEM guard is gone: a raw ell_spmm call with a source past the
    cap must trace and run (interpret emulates the DMA protocol exactly)."""
    assert default_stream() is True
    rng = np.random.default_rng(0)
    m = OLD_CAP_ROWS + 4096   # past the old 12 MiB guard threshold
    idx = jnp.asarray(rng.integers(0, m, (256, 4)).astype(np.int32))
    w = jnp.asarray(rng.random((256, 4)).astype(np.float32))
    h = jnp.asarray(rng.normal(size=(m, 128)).astype(np.float32))
    out = ell_spmm(idx, w, h)   # old guard raised ValueError here
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ell_spmm_ref(idx, w, h)),
                               rtol=2e-5, atol=2e-5)


def test_streamed_path_lowers_and_compiles():
    """interpret=False + stream=True must lower + compile with gather sources
    beyond the old cap (TPU-only: Mosaic cannot lower on CPU) — mirrors
    test_compiled_path_lowers_and_compiles for the streamed kernels."""
    if jax.default_backend() != "tpu":
        pytest.skip("no TPU in this container; compiled Mosaic lowering "
                    "requires a TPU backend")
    rng = np.random.default_rng(0)
    m = BIG_M
    idx = jnp.asarray(rng.integers(0, m, (256, 8)).astype(np.int32))
    w = jnp.asarray(rng.random((256, 8)).astype(np.float32))
    h = jnp.asarray(rng.normal(size=(m, 128)).astype(np.float32))
    jax.jit(lambda a, b, c: ell_spmm(a, b, c, interpret=False,
                                     stream=True)).lower(idx, w, h).compile()
    store = jnp.asarray(rng.normal(size=(m, 128)).astype(np.float32))
    gids = jnp.asarray(rng.integers(0, m, 256).astype(np.int32))
    beta = jnp.asarray(rng.random(256).astype(np.float32))
    mask = jnp.asarray((rng.random(256) > 0.5).astype(np.float32))
    fresh = jnp.asarray(rng.normal(size=(256, 128)).astype(np.float32))
    jax.jit(lambda *a: lmc_compensate(*a, interpret=False,
                                      stream=True)).lower(
        store, gids, beta, fresh, mask).compile()
