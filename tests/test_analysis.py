"""repro.analysis: per-rule trigger/non-trigger fixtures + the self-host gate.

Every rule gets at least one minimal source fixture that must fire and one
that must stay silent (including the deliberately unpaired DMA wait and the
oversized resident BlockSpec the acceptance criteria call out), the pragma
mechanism is exercised both ways (suppresses with a reason, refuses without),
and the whole catalog runs self-hosted over src/ — the tier-1 guarantee that
the tree carries zero unsuppressed findings.
"""
import subprocess
import sys
import textwrap
from pathlib import Path

from repro.analysis import analyze_source, run_analysis, summarize
from repro.analysis.engine import all_rules

REPO = Path(__file__).resolve().parent.parent
SRC = REPO / "src"


def findings(src, rule=None, path="fixture.py"):
    fs = analyze_source(textwrap.dedent(src), path=path)
    if rule is not None:
        fs = [f for f in fs if f.rule == rule]
    return fs


def live(src, rule=None, path="fixture.py"):
    return [f for f in findings(src, rule, path) if not f.suppressed]


# ---------------------------------------------------------------- R001
CONCAT_BAD = """
    import jax.numpy as jnp
    def f(a, b):
        return jnp.concatenate([a, b], axis=0)
"""

STACK_BAD = """
    import jax.numpy as jnp
    def f(xs):
        return jnp.stack(xs)
"""

CONCAT_ALIASED = """
    from jax.numpy import concatenate as cat
    def f(a, b):
        return cat([a, b])
"""

CONCAT_OK = """
    import numpy as np
    from repro.dist.sharding import concat_rows
    def f(a, b):
        host = np.concatenate([a, b])        # host-side numpy: fine
        return concat_rows([a, b], axis=0)
"""

CONCAT_PRAGMA = """
    import jax.numpy as jnp
    def f(a, b):
        # lint: ok(R001) operands are per-host python scalars, never sharded
        return jnp.concatenate([a, b], axis=0)
"""


def test_r001_flags_concat_stack_and_aliases():
    assert len(live(CONCAT_BAD, "R001")) == 1
    assert len(live(STACK_BAD, "R001")) == 1
    assert len(live(CONCAT_ALIASED, "R001")) == 1


def test_r001_silent_on_numpy_and_concat_rows():
    assert live(CONCAT_OK, "R001") == []


def test_r001_allowlists_sharding_module():
    assert live(CONCAT_BAD, "R001",
                path="src/repro/dist/sharding.py") == []


def test_r001_pragma_suppresses_with_reason():
    fs = findings(CONCAT_PRAGMA, "R001")
    assert len(fs) == 1 and fs[0].suppressed
    assert "scalars" in fs[0].reason


# ---------------------------------------------------------------- R002
_DMA_PRELUDE = """
    import jax
    from jax.experimental import pallas as pl
    from jax.experimental.pallas import tpu as pltpu
"""

DMA_UNPAIRED_START = _DMA_PRELUDE + """
    def kern(h_ref, o_ref, buf_ref, sem_ref):
        pltpu.make_async_copy(h_ref.at[0], buf_ref.at[0], sem_ref.at[0]).start()
        o_ref[:] = buf_ref[0]
"""

DMA_UNPAIRED_WAIT = _DMA_PRELUDE + """
    def kern(h_ref, o_ref, buf_ref, sem_ref):
        pltpu.make_async_copy(h_ref.at[0], buf_ref.at[0], sem_ref.at[0]).wait()
        o_ref[:] = buf_ref[0]
"""

DMA_PAIRED = _DMA_PRELUDE + """
    def kern(h_ref, o_ref, buf_ref, sem_ref):
        pltpu.make_async_copy(h_ref.at[0], buf_ref.at[0], sem_ref.at[0]).start()
        o_ref[:] = o_ref[:] * 0
        pltpu.make_async_copy(h_ref.at[0], buf_ref.at[0], sem_ref.at[0]).wait()
"""

DMA_NAMED_PAIRED = _DMA_PRELUDE + """
    def kern(h_ref, o_ref, buf_ref, sem_ref):
        dma = pltpu.make_async_copy(h_ref.at[0], buf_ref.at[0], sem_ref.at[0])
        dma.start()
        dma.wait()
"""

DMA_NAMED_NO_WAIT = _DMA_PRELUDE + """
    def kern(h_ref, o_ref, buf_ref, sem_ref):
        dma = pltpu.make_async_copy(h_ref.at[0], buf_ref.at[0], sem_ref.at[0])
        dma.start()
"""

# the repo's double-buffer idiom: a helper applying an `op` parameter
DMA_HELPER_BOTH = _DMA_PRELUDE + """
    def kern(idx_ref, h_ref, o_ref, buf_ref, sem_ref):
        def plane(k, slot, op):
            op(pltpu.make_async_copy(h_ref.at[k], buf_ref.at[slot],
                                     sem_ref.at[slot]))
        plane(0, 0, lambda dma: dma.start())
        plane(0, 0, lambda dma: dma.wait())
"""

DMA_HELPER_START_ONLY = _DMA_PRELUDE + """
    def kern(idx_ref, h_ref, o_ref, buf_ref, sem_ref):
        def plane(k, slot, op):
            op(pltpu.make_async_copy(h_ref.at[k], buf_ref.at[slot],
                                     sem_ref.at[slot]))
        plane(0, 0, lambda dma: dma.start())
        plane(1, 1, lambda dma: dma.start())
"""

DMA_SLOT_MISMATCH = _DMA_PRELUDE + """
    import jax.numpy as jnp
    def kern(h_ref, o_ref, buf_ref, sem_ref):
        pltpu.make_async_copy(h_ref.at[0], buf_ref.at[0], sem_ref.at[0]).start()
        pltpu.make_async_copy(h_ref.at[0], buf_ref.at[0], sem_ref.at[0]).wait()
    def call(h):
        return pl.pallas_call(
            kern,
            out_shape=h,
            scratch_shapes=[pltpu.VMEM((3, 256, 128), jnp.float32),
                            pltpu.SemaphoreType.DMA((2,))],
        )(h)
"""

DMA_REM_MISMATCH = _DMA_PRELUDE + """
    import jax.numpy as jnp
    def kern(h_ref, o_ref, buf_ref, sem_ref):
        slot = jax.lax.rem(pl.program_id(0), 3)
        pltpu.make_async_copy(h_ref.at[0], buf_ref.at[slot],
                              sem_ref.at[slot]).start()
        pltpu.make_async_copy(h_ref.at[0], buf_ref.at[slot],
                              sem_ref.at[slot]).wait()
    def call(h):
        return pl.pallas_call(
            kern,
            out_shape=h,
            scratch_shapes=[pltpu.VMEM((2, 256, 128), jnp.float32),
                            pltpu.SemaphoreType.DMA((2,))],
        )(h)
"""


def test_r002_unpaired_start_and_wait():
    (f,) = live(DMA_UNPAIRED_START, "R002")
    assert "never waited" in f.message
    (f,) = live(DMA_UNPAIRED_WAIT, "R002")
    assert "never started" in f.message and "deadlock" in f.message


def test_r002_silent_on_paired_copies():
    assert live(DMA_PAIRED, "R002") == []
    assert live(DMA_NAMED_PAIRED, "R002") == []


def test_r002_named_handle_without_wait():
    (f,) = live(DMA_NAMED_NO_WAIT, "R002")
    assert "never `.wait()`ed" in f.message


def test_r002_helper_op_idiom():
    assert live(DMA_HELPER_BOTH, "R002") == []
    (f,) = live(DMA_HELPER_START_ONLY, "R002")
    assert "plane" in f.message and ".wait()" in f.message


def test_r002_slot_count_vs_semaphore_shape():
    (f,) = live(DMA_SLOT_MISMATCH, "R002")
    assert "3 slot(s)" in f.message and "2" in f.message


def test_r002_rem_modulus_vs_semaphores():
    (f,) = live(DMA_REM_MISMATCH, "R002")
    assert "rem(_, 3)" in f.message


# ---------------------------------------------------------------- R003
VMEM_OVERSIZED = """
    from jax.experimental import pallas as pl
    def f():
        # (32768, 256) f32 = 32 MiB: over the ~12 MiB Mosaic ceiling
        return pl.BlockSpec((32768, 256), lambda i, j: (i, j))
"""

VMEM_UNBOUNDED = """
    from jax.experimental import pallas as pl
    def f(h, block_d: int = 128):
        m = h.shape[0]
        return pl.BlockSpec((m, block_d), lambda i, j: (0, j))
"""

VMEM_OK_DEFAULTS = """
    from jax.experimental import pallas as pl
    from jax.experimental.pallas import tpu as pltpu
    import jax.numpy as jnp
    def f(block_rows: int = 256, block_d: int = 128):
        spec = pl.BlockSpec((block_rows, block_d), lambda i, j: (i, j))
        scratch = pltpu.VMEM((2, block_rows, block_d), jnp.float32)
        return spec, scratch
"""

VMEM_SCRATCH_OVERSIZED = """
    from jax.experimental.pallas import tpu as pltpu
    import jax.numpy as jnp
    def f():
        return pltpu.VMEM((4096, 1024), jnp.float32)   # 16 MiB
"""

VMEM_AGGREGATE = """
    from jax.experimental.pallas import tpu as pltpu
    import jax.numpy as jnp
    def f():
        a = pltpu.VMEM((2048, 1024), jnp.float32)      # 8 MiB
        b = pltpu.VMEM((2048, 1024), jnp.float32)      # 8 MiB: sum 16 MiB
        return a, b
"""

VMEM_BF16_UNDER = """
    from jax.experimental.pallas import tpu as pltpu
    import jax.numpy as jnp
    def f():
        # 4096*1024 bf16 = 8 MiB: only over budget if dtype size is wrong
        return pltpu.VMEM((4096, 1024), jnp.bfloat16)
"""


def test_r003_oversized_blockspec():
    (f,) = live(VMEM_OVERSIZED, "R003")
    assert "32.0 MiB" in f.message


def test_r003_unbounded_resident_block():
    (f,) = live(VMEM_UNBOUNDED, "R003")
    assert "runtime-valued" in f.message and "`pltpu.ANY`" in f.message


def test_r003_resolves_param_defaults_and_dtypes():
    assert live(VMEM_OK_DEFAULTS, "R003") == []
    assert live(VMEM_BF16_UNDER, "R003") == []
    (f,) = live(VMEM_SCRATCH_OVERSIZED, "R003")
    assert "16.0 MiB" in f.message


def test_r003_aggregate_budget():
    (f,) = live(VMEM_AGGREGATE, "R003")
    assert "sum to 16.0 MiB" in f.message


# ---------------------------------------------------------------- R004
JIT_BRANCH = """
    import jax
    @jax.jit
    def f(x):
        if x > 0:
            return x
        return -x
"""

JIT_ITEM = """
    import jax
    @jax.jit
    def f(x):
        return x.sum().item()
"""

JIT_NP_ASARRAY = """
    import jax
    import numpy as np
    @jax.jit
    def f(x):
        return np.asarray(x)
"""

JIT_STATIC_BRANCH = """
    import functools
    import jax
    @functools.partial(jax.jit, static_argnames=("flag",))
    def f(x, flag):
        if flag:
            return x
        return -x
"""

JIT_SAFE_TESTS = """
    import jax
    @jax.jit
    def f(x, y):
        if y is None:
            return x
        if x.shape[0] > 2:
            return x + y
        return x - y
"""

VJP_BRANCH = """
    import jax
    @jax.custom_vjp
    def f(x):
        return x
    def f_fwd(x):
        return f(x), (x,)
    def f_bwd(res, ct):
        (x,) = res
        if ct > 0:
            return (ct,)
        return (-ct,)
    f.defvjp(f_fwd, f_bwd)
"""

UNJITTED_BRANCH = """
    def f(x):
        if x > 0:
            return x
        return -x
"""


def test_r004_branch_on_traced_param():
    (f,) = live(JIT_BRANCH, "R004")
    assert "`if` on traced value(s) `x`" in f.message


def test_r004_host_syncs():
    (f,) = live(JIT_ITEM, "R004")
    assert ".item()" in f.message
    (f,) = live(JIT_NP_ASARRAY, "R004")
    assert "numpy.asarray" in f.message


def test_r004_static_argnames_exempt():
    assert live(JIT_STATIC_BRANCH, "R004") == []


def test_r004_structural_and_shape_tests_exempt():
    assert live(JIT_SAFE_TESTS, "R004") == []


def test_r004_covers_defvjp_registered_functions():
    fs = live(VJP_BRANCH, "R004")
    assert len(fs) == 1 and "ct" in fs[0].message


def test_r004_ignores_untraced_functions():
    assert live(UNJITTED_BRANCH, "R004") == []


# ---------------------------------------------------------------- R005
VJP_OK = """
    import functools
    import jax
    @functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
    def f(flag, x, y):
        return x * y
    def f_fwd(flag, x, y):
        return f(flag, x, y), (x, y)
    def f_bwd(flag, res, ct):
        x, y = res
        return (ct * y, ct * x)
    f.defvjp(f_fwd, f_bwd)
"""

VJP_RESIDUAL_DRIFT = VJP_OK.replace("x, y = res", "x, y, z = res")

VJP_BWD_PARAMS = VJP_OK.replace("def f_bwd(flag, res, ct):",
                                "def f_bwd(res, ct):")

VJP_BWD_RETURN = VJP_OK.replace("return (ct * y, ct * x)",
                                "return (ct * y, ct * x, None)")

VJP_FWD_PARAMS = VJP_OK.replace("def f_fwd(flag, x, y):",
                                "def f_fwd(flag, x):")

VJP_FWD_RETURN = VJP_OK.replace("return f(flag, x, y), (x, y)",
                                "return f(flag, x, y), x, y")


def test_r005_consistent_trio_is_silent():
    assert live(VJP_OK, "R005") == []


def test_r005_residual_arity_drift():
    (f,) = live(VJP_RESIDUAL_DRIFT, "R005")
    assert "unpacks 3" in f.message and "saves 2" in f.message


def test_r005_bwd_param_count():
    (f,) = live(VJP_BWD_PARAMS, "R005")
    assert "takes 2 parameter(s), expected 3" in f.message


def test_r005_bwd_return_arity():
    (f,) = live(VJP_BWD_RETURN, "R005")
    assert "returns 3 cotangent(s), expected 2" in f.message


def test_r005_fwd_signature_and_return():
    (f,) = live(VJP_FWD_PARAMS, "R005")
    assert "takes 2 parameter(s) but the primal" in f.message
    (f,) = live(VJP_FWD_RETURN, "R005")
    assert "must return `(out, residuals)`" in f.message


# ---------------------------------------------------------------- R006
QUEUE_PATH = "src/repro/serve/fixture.py"      # in the rule's scoped dirs

QUEUE_UNBOUNDED = """
    import queue
    q = queue.Queue()
    lq = queue.LifoQueue(maxsize=0)
    sq = queue.SimpleQueue()
"""

QUEUE_BOUNDED = """
    import queue
    q = queue.Queue(maxsize=8)
    p = queue.PriorityQueue(16)
"""

QUEUE_BLOCKING = """
    def f(q, t):
        item = q.get()
        q.put(item)
        t.join()
"""

QUEUE_NONBLOCKING = """
    def f(q, t, xs, d):
        a = q.get(timeout=0.1)
        b = q.get(block=False)
        c = q.get_nowait()
        q.put(a, timeout=1.0)
        q.put_nowait(b)
        t.join(timeout=2.0)
        s = ",".join(xs)          # str.join takes an arg: not the queue shape
        v = d.get("k", 0)         # dict.get with default: not the queue shape
        return a, b, c, s, v
"""

QUEUE_PRAGMA = """
    import queue
    # lint: ok(R006) request ordering needs FIFO of unbounded test fixtures
    q = queue.Queue()
"""


def test_r006_flags_unbounded_queues():
    fs = live(QUEUE_UNBOUNDED, "R006", path=QUEUE_PATH)
    assert len(fs) == 3
    assert any("SimpleQueue" in f.message for f in fs)
    assert all("maxsize" in f.message for f in fs[:2])


def test_r006_silent_on_bounded_queues():
    assert live(QUEUE_BOUNDED, "R006", path=QUEUE_PATH) == []


def test_r006_flags_blocking_calls():
    fs = live(QUEUE_BLOCKING, "R006", path=QUEUE_PATH)
    assert sorted(f.message.split("`")[1] for f in fs) == \
        [".get()", ".join()", ".put()"]
    assert all("timeout=" in f.message for f in fs)


def test_r006_silent_on_timeout_nowait_and_lookalikes():
    assert live(QUEUE_NONBLOCKING, "R006", path=QUEUE_PATH) == []


def test_r006_scoped_to_threaded_tiers():
    """The same source outside src/repro/{data,serve} is not this rule's
    business — kernels and training code get to block."""
    assert live(QUEUE_UNBOUNDED, "R006", path="src/repro/train/loop.py") == []
    assert live(QUEUE_BLOCKING, "R006", path="benchmarks/bench_serve.py") == []


def test_r006_pragma_suppresses_with_reason():
    assert live(QUEUE_PRAGMA, "R006", path=QUEUE_PATH) == []
    (f,) = [f for f in findings(QUEUE_PRAGMA, "R006", path=QUEUE_PATH)]
    assert f.suppressed and "FIFO" in f.reason


# ------------------------------------------------------- pragmas & engine
def test_reasonless_pragma_does_not_suppress():
    src = CONCAT_PRAGMA.replace(
        "# lint: ok(R001) operands are per-host python scalars, never sharded",
        "# lint: ok(R001)")
    fs = findings(src)
    assert any(f.rule == "R001" and not f.suppressed for f in fs)
    assert any(f.rule == "R000" and "reason" in f.message for f in fs)


def test_pragma_in_comment_block_above():
    src = """
    import jax.numpy as jnp
    def f(a, b):
        # lint: ok(R001) fixture: operands replicated
        # (continued explanation on a second comment line)
        return jnp.concatenate([a, b], axis=0)
    """
    assert live(src, "R001") == []


def test_multi_rule_pragma():
    src = """
    import jax.numpy as jnp
    def f(a, b):
        # lint: ok(R001,R004) fixture: replicated scalars
        return jnp.stack([a, b])
    """
    assert live(src, "R001") == []


def test_syntax_error_is_a_finding():
    fs = findings("def f(:\n")
    assert fs and fs[0].rule == "R000" and "parse" in fs[0].message


def test_rule_catalog_ids_unique_and_documented():
    rules = all_rules()
    ids = [r.id for r in rules]
    assert ids == sorted(set(ids)) == ["R001", "R002", "R003", "R004",
                                       "R005", "R006"]
    assert all(r.name and r.doc for r in rules)


# ------------------------------------------------------- self-host + CLI
def test_self_hosted_src_is_clean():
    """The standing guarantee: zero unsuppressed findings over src/."""
    fs = run_analysis([SRC])
    bad = [f for f in fs if not f.suppressed]
    assert bad == [], "\n" + "\n".join(f.format() for f in bad)
    # ...and the audits it machine-checks are actually present as pragmas
    assert any(f.rule == "R001" and f.suppressed for f in fs)
    assert any(f.rule == "R003" and f.suppressed for f in fs)


def test_cli_exit_codes(tmp_path):
    env_src = str(SRC)
    ok = subprocess.run(
        [sys.executable, "-m", "repro.analysis", env_src],
        capture_output=True, text=True, env=_env())
    assert ok.returncode == 0, ok.stdout + ok.stderr
    assert "unsuppressed finding" in ok.stdout

    bad = tmp_path / "bad.py"
    bad.write_text(textwrap.dedent(CONCAT_BAD))
    res = subprocess.run(
        [sys.executable, "-m", "repro.analysis", str(bad)],
        capture_output=True, text=True, env=_env())
    assert res.returncode == 1
    assert "R001" in res.stdout

    unknown = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--rule", "R999", env_src],
        capture_output=True, text=True, env=_env())
    assert unknown.returncode == 2


def test_cli_rule_filter_and_json(tmp_path):
    import json as _json
    bad = tmp_path / "bad.py"
    bad.write_text(textwrap.dedent(CONCAT_BAD))
    res = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--rule", "R002",
         str(bad)], capture_output=True, text=True, env=_env())
    assert res.returncode == 0          # R001 site, but only R002 requested
    res = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--json", str(bad)],
        capture_output=True, text=True, env=_env())
    assert res.returncode == 1
    data = _json.loads(res.stdout)
    assert any(f["rule"] == "R001" for f in data)


def test_summary_has_per_rule_lines():
    out = summarize(run_analysis([SRC]))
    for rid in ("R001", "R002", "R003", "R004", "R005", "R006"):
        assert rid in out
    assert "0 unsuppressed" in out


def _env():
    import os
    env = dict(os.environ)
    env["PYTHONPATH"] = str(SRC) + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    return env
