"""Tests for repro.data.prefetch — ordering, bounded lookahead, exception
propagation, and prompt close() even with a blocked worker."""
import threading
import time

import pytest

from repro.data.prefetch import Prefetcher


def _wait_until(pred, timeout=5.0):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return True
        time.sleep(0.01)
    return pred()


def test_yields_all_items_in_order():
    p = Prefetcher(iter(range(100)))
    assert list(p) == list(range(100))


def test_exhausted_stream_stays_exhausted():
    p = Prefetcher(iter([1]))
    assert list(p) == [1]
    with pytest.raises(StopIteration):
        next(p)  # must not hang on the drained sentinel


def test_empty_source():
    assert list(Prefetcher(iter([]))) == []


def test_depth_must_be_positive():
    with pytest.raises(ValueError):
        Prefetcher(iter([]), depth=0)


def test_bounded_lookahead():
    """The worker never runs more than `depth` items ahead of the consumer."""
    produced = []

    def source():
        for i in range(50):
            produced.append(i)
            yield i

    depth = 3
    p = Prefetcher(source(), depth=depth)
    try:
        got = [next(p) for _ in range(5)]
        assert got == list(range(5))
        # give the worker time to run as far ahead as the queue allows;
        # +1 for the item it may hold while blocked in put()
        _wait_until(lambda: len(produced) >= 5 + depth)
        time.sleep(0.1)
        assert len(produced) <= 5 + depth + 1
    finally:
        p.close()


def test_exception_propagates_after_good_items():
    def source():
        yield 1
        yield 2
        raise RuntimeError("bad batch")

    p = Prefetcher(source())
    assert next(p) == 1
    assert next(p) == 2
    with pytest.raises(RuntimeError, match="bad batch"):
        next(p)
    # iterator stays exhausted, does not hang
    with pytest.raises(StopIteration):
        next(p)


def test_exception_on_first_item():
    def source():
        raise ValueError("boom")
        yield  # pragma: no cover

    with pytest.raises(ValueError, match="boom"):
        next(Prefetcher(source()))


def test_close_unblocks_full_queue_worker():
    """close() must terminate a worker stuck in a full-queue put."""
    release = threading.Event()

    def source():
        for i in range(1000):
            yield i
        release.set()  # only reached if the worker ran to completion

    p = Prefetcher(source(), depth=1)
    # let the worker fill the queue and block in put()
    _wait_until(lambda: p.q.full())
    p.close()
    assert _wait_until(lambda: not p._thread.is_alive()), (
        "worker thread still alive after close()")
    assert not release.is_set(), "worker should have stopped early"
    with pytest.raises(StopIteration):
        next(p)


def test_close_is_idempotent():
    p = Prefetcher(iter(range(10)))
    p.close()
    p.close()
    with pytest.raises(StopIteration):
        next(p)


def test_sentinel_collision_safe():
    """A source yielding exotic values (including the StopIteration class
    itself) must round-trip — the old implementation used StopIteration as
    its end-of-stream sentinel and would truncate this stream."""
    items = [None, StopIteration, 0, ""]
    assert list(Prefetcher(iter(items))) == items
