"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, strategies as st

from repro.kernels import (build_ell, bucketed_spmm, ell_aggregate_fn,
                           ell_spmm, lmc_compensate)
from repro.kernels.ref import (degree_bucket_spmm_ref, ell_spmm_ref,
                               lmc_compensate_ref)


@given(n_tiles=st.integers(1, 2), k=st.sampled_from([4, 8, 32]),
       d_tiles=st.integers(1, 2), m=st.sampled_from([64, 300, 1000]),
       dtype=st.sampled_from([np.float32]), seed=st.integers(0, 100))
@settings(max_examples=16)
def test_ell_spmm_matches_ref(n_tiles, k, d_tiles, m, dtype, seed):
    rng = np.random.default_rng(seed)
    n, d = 256 * n_tiles, 128 * d_tiles
    idx = rng.integers(0, m, (n, k)).astype(np.int32)
    w = (rng.random((n, k)) * (rng.random((n, k)) > 0.3)).astype(dtype)
    h = rng.normal(size=(m, d)).astype(dtype)
    out = ell_spmm(jnp.asarray(idx), jnp.asarray(w), jnp.asarray(h))
    ref = ell_spmm_ref(jnp.asarray(idx), jnp.asarray(w), jnp.asarray(h))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_ell_spmm_bf16():
    rng = np.random.default_rng(0)
    idx = rng.integers(0, 64, (256, 8)).astype(np.int32)
    w = rng.random((256, 8)).astype(np.float32)
    h = rng.normal(size=(64, 128)).astype(jnp.bfloat16)
    out = ell_spmm(jnp.asarray(idx), jnp.asarray(w).astype(jnp.bfloat16),
                   jnp.asarray(h))
    ref = ell_spmm_ref(jnp.asarray(idx),
                       jnp.asarray(w).astype(jnp.bfloat16), jnp.asarray(h))
    np.testing.assert_allclose(np.float32(out), np.float32(ref),
                               rtol=5e-2, atol=5e-2)


@given(seed=st.integers(0, 50), beta_max=st.floats(0.0, 1.0))
@settings(max_examples=10)
def test_lmc_compensate_matches_ref(seed, beta_max):
    rng = np.random.default_rng(seed)
    n, m, d = 256, 500, 128
    store = rng.normal(size=(m, d)).astype(np.float32)
    gids = rng.integers(0, m, n).astype(np.int32)
    beta = (rng.random(n) * beta_max).astype(np.float32)
    mask = (rng.random(n) > 0.2).astype(np.float32)
    fresh = rng.normal(size=(n, d)).astype(np.float32)
    args = [jnp.asarray(a) for a in (store, gids, beta, fresh, mask)]
    np.testing.assert_allclose(np.asarray(lmc_compensate(*args)),
                               np.asarray(lmc_compensate_ref(*args)),
                               rtol=1e-6, atol=1e-6)


def test_bucketed_spmm_on_real_graph(small_graph):
    g = small_graph
    rng = np.random.default_rng(0)
    row = np.repeat(np.arange(g.num_nodes), np.diff(g.indptr))
    ws = g.gcn_edge_weights(g.indices.astype(np.int64), row)
    ell = build_ell(g.indptr, g.indices, ws)
    h = rng.normal(size=(g.num_nodes, 50)).astype(np.float32)
    out = bucketed_spmm(ell, jnp.asarray(h))
    ref = degree_bucket_spmm_ref(jnp.asarray(g.indptr), jnp.asarray(g.indices),
                                 jnp.asarray(ws), jnp.asarray(h))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-4, atol=3e-4)


def test_gnn_forward_with_kernel_aggregate(small_graph):
    """Swapping the jnp aggregation for the Pallas kernel is output-identical."""
    from repro.core import from_graph
    from repro.models import make_gnn
    g = small_graph
    data = from_graph(g)
    row = np.repeat(np.arange(g.num_nodes), np.diff(g.indptr))
    ws = g.gcn_edge_weights(g.indices.astype(np.int64), row)
    ell = build_ell(g.indptr, g.indices, ws)

    gnn_ref = make_gnn("gcn", g.feature_dim, 32, g.num_classes, 2)
    gnn_krn = make_gnn("gcn", g.feature_dim, 32, g.num_classes, 2,
                       aggregate=ell_aggregate_fn(ell))
    params = gnn_ref.init_params(jax.random.key(0))
    out_ref = gnn_ref.full_forward(params, data.x, data.edges, data.self_w)
    out_krn = gnn_krn.full_forward(params, data.x, data.edges, data.self_w)
    np.testing.assert_allclose(np.asarray(out_krn), np.asarray(out_ref),
                               rtol=2e-3, atol=2e-3)
