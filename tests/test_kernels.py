"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps,
gradient paths through the custom VJPs, and the vectorized ELL builder."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, strategies as st

from repro.kernels import (ELLCapacityError, ELLGraph, build_ell,
                           bucketed_spmm, default_interpret, ell_aggregate_fn,
                           ell_from_coo, ell_spmm, lmc_compensate)
from repro.kernels.ops import _build_ell_loop
from repro.kernels.ref import (degree_bucket_spmm_ref, ell_spmm_ref,
                               lmc_compensate_ref)


def _random_csr(seed, n_max=60, heavy=True):
    """Random CSR with deg-0 rows and (optionally) heavy rows > max bucket."""
    r = np.random.default_rng(seed)
    n = int(r.integers(5, n_max))
    choices = [0, 1, 3, 7, 8, 20] + ([130, 300] if heavy else [])
    p = np.ones(len(choices)) / len(choices)
    deg = r.choice(choices, size=n, p=p)
    indptr = np.zeros(n + 1, np.int64)
    indptr[1:] = np.cumsum(deg)
    nnz = int(indptr[-1])
    indices = r.integers(0, n, nnz).astype(np.int32)
    weights = r.random(nnz).astype(np.float32)
    return indptr, indices, weights


@given(n_tiles=st.integers(1, 2), k=st.sampled_from([4, 8, 32]),
       d_tiles=st.integers(1, 2), m=st.sampled_from([64, 300, 1000]),
       dtype=st.sampled_from([np.float32]), seed=st.integers(0, 100))
@settings(max_examples=16)
def test_ell_spmm_matches_ref(n_tiles, k, d_tiles, m, dtype, seed):
    rng = np.random.default_rng(seed)
    n, d = 256 * n_tiles, 128 * d_tiles
    idx = rng.integers(0, m, (n, k)).astype(np.int32)
    w = (rng.random((n, k)) * (rng.random((n, k)) > 0.3)).astype(dtype)
    h = rng.normal(size=(m, d)).astype(dtype)
    out = ell_spmm(jnp.asarray(idx), jnp.asarray(w), jnp.asarray(h))
    ref = ell_spmm_ref(jnp.asarray(idx), jnp.asarray(w), jnp.asarray(h))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_ell_spmm_bf16():
    rng = np.random.default_rng(0)
    idx = rng.integers(0, 64, (256, 8)).astype(np.int32)
    w = rng.random((256, 8)).astype(np.float32)
    h = rng.normal(size=(64, 128)).astype(jnp.bfloat16)
    out = ell_spmm(jnp.asarray(idx), jnp.asarray(w).astype(jnp.bfloat16),
                   jnp.asarray(h))
    ref = ell_spmm_ref(jnp.asarray(idx),
                       jnp.asarray(w).astype(jnp.bfloat16), jnp.asarray(h))
    np.testing.assert_allclose(np.float32(out), np.float32(ref),
                               rtol=5e-2, atol=5e-2)


@given(seed=st.integers(0, 50), beta_max=st.floats(0.0, 1.0))
@settings(max_examples=10)
def test_lmc_compensate_matches_ref(seed, beta_max):
    rng = np.random.default_rng(seed)
    n, m, d = 256, 500, 128
    store = rng.normal(size=(m, d)).astype(np.float32)
    gids = rng.integers(0, m, n).astype(np.int32)
    beta = (rng.random(n) * beta_max).astype(np.float32)
    mask = (rng.random(n) > 0.2).astype(np.float32)
    fresh = rng.normal(size=(n, d)).astype(np.float32)
    args = [jnp.asarray(a) for a in (store, gids, beta, fresh, mask)]
    np.testing.assert_allclose(np.asarray(lmc_compensate(*args)),
                               np.asarray(lmc_compensate_ref(*args)),
                               rtol=1e-6, atol=1e-6)


def test_bucketed_spmm_on_real_graph(small_graph):
    g = small_graph
    rng = np.random.default_rng(0)
    row = np.repeat(np.arange(g.num_nodes), np.diff(g.indptr))
    ws = g.gcn_edge_weights(g.indices.astype(np.int64), row)
    ell = build_ell(g.indptr, g.indices, ws)
    h = rng.normal(size=(g.num_nodes, 50)).astype(np.float32)
    out = bucketed_spmm(ell, jnp.asarray(h))
    ref = degree_bucket_spmm_ref(jnp.asarray(g.indptr), jnp.asarray(g.indices),
                                 jnp.asarray(ws), jnp.asarray(h))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-4, atol=3e-4)


# ------------------------------------------------------ vectorized build_ell
@given(seed=st.integers(0, 200))
@settings(max_examples=12)
def test_build_ell_vectorized_matches_loop(seed):
    """The bulk-numpy builder reproduces the original per-node loop exactly:
    same bucketing, same heavy-row splitting, same row order, same padding."""
    indptr, indices, weights = _random_csr(seed)
    g_vec = build_ell(indptr, indices, weights, with_transpose=False)
    g_loop = _build_ell_loop(indptr, indices, weights)
    assert g_vec.num_rows == g_loop.num_rows
    for a, b in zip(g_vec.bucket_idx + g_vec.bucket_w + g_vec.bucket_rows,
                    g_loop.bucket_idx + g_loop.bucket_w + g_loop.bucket_rows):
        assert a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_build_ell_edgeless_graph():
    """A graph with zero edges builds (all-padding deg-0 rows) and SpMMs to 0,
    matching the loop builder."""
    n = 10
    indptr = np.zeros(n + 1, np.int64)
    g_vec = build_ell(indptr, np.zeros(0, np.int32), np.zeros(0, np.float32))
    g_loop = _build_ell_loop(indptr, np.zeros(0, np.int32),
                             np.zeros(0, np.float32))
    for a, b in zip(g_vec.bucket_idx + g_vec.bucket_w + g_vec.bucket_rows,
                    g_loop.bucket_idx + g_loop.bucket_w + g_loop.bucket_rows):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    out = bucketed_spmm(g_vec, jnp.ones((n, 8), jnp.float32))
    np.testing.assert_array_equal(np.asarray(out), np.zeros((n, 8)))


def test_build_ell_transpose_is_adjoint():
    """⟨A h, y⟩ == ⟨h, Aᵀ y⟩ with both sides computed by the kernel."""
    indptr, indices, weights = _random_csr(7)
    n = indptr.shape[0] - 1
    g = build_ell(indptr, indices, weights, block_rows=64)
    rng = np.random.default_rng(0)
    h = jnp.asarray(rng.normal(size=(n, 24)).astype(np.float32))
    y = jnp.asarray(rng.normal(size=(n, 24)).astype(np.float32))
    lhs = jnp.vdot(bucketed_spmm(g, h), y)
    rhs = jnp.vdot(h, bucketed_spmm(g.transpose, y))
    np.testing.assert_allclose(float(lhs), float(rhs), rtol=1e-4)


def test_ell_from_coo_fixed_capacity_shapes():
    """Two batches with the same (rows, E) envelope -> identical jit shapes."""
    rng = np.random.default_rng(0)
    n, e = 100, 400
    shapes = []
    for seed in range(2):
        r = np.random.default_rng(seed)
        g = ell_from_coo(r.integers(0, n, e), r.integers(0, n, e),
                         r.random(e).astype(np.float32), n)
        shapes.append(jax.tree.map(lambda x: x.shape, g))
    assert shapes[0] == shapes[1]


@given(seed=st.integers(0, 100))
@settings(max_examples=10)
def test_ell_from_coo_zero_degree_rows(seed):
    """Rows with no incoming edges emit an (empty) bucket-0 row rather than
    vanishing: they aggregate to exactly 0 and every other row matches the
    scatter-add oracle."""
    r = np.random.default_rng(seed)
    n, e = 64, 120
    src = r.integers(0, n, e)
    dst = r.integers(0, n // 2, e)   # rows [n/2, n) have zero in-degree
    w = r.random(e).astype(np.float32)
    g = ell_from_coo(src, dst, w, n)
    h = r.normal(size=(n, 16)).astype(np.float32)
    out = np.asarray(bucketed_spmm(g, jnp.asarray(h)))
    ref_out = np.zeros((n, 16), np.float32)
    np.add.at(ref_out, dst, w[:, None] * h[src])
    np.testing.assert_allclose(out, ref_out, rtol=2e-4, atol=1e-5)
    np.testing.assert_array_equal(out[n // 2:], 0.0)


def test_build_ell_exactly_at_capacity():
    """rows == capacity is legal: no padding rows, no error, exact results."""
    n = 8                             # 8 deg-[1..8] nodes -> 8 bucket-0 rows
    r = np.random.default_rng(0)
    deg = np.arange(1, n + 1)
    indptr = np.zeros(n + 1, np.int64)
    indptr[1:] = np.cumsum(deg)
    indices = r.integers(0, n, int(indptr[-1])).astype(np.int32)
    weights = r.random(int(indptr[-1])).astype(np.float32)
    g = build_ell(indptr, indices, weights, row_capacity=(8, 8, 8))
    assert g.bucket_idx[0].shape[0] == 8          # exactly full, zero pad rows
    h = r.normal(size=(n, 8)).astype(np.float32)
    out = np.asarray(bucketed_spmm(g, jnp.asarray(h)))
    ref_out = np.zeros((n, 8), np.float32)
    src = np.repeat(np.arange(n), deg)
    np.add.at(ref_out, src, weights[:, None] * h[indices])
    np.testing.assert_allclose(out, ref_out, rtol=2e-4, atol=1e-5)


def test_build_ell_overflow_raises_named_error():
    """One row over capacity raises ELLCapacityError (a ValueError, so legacy
    handlers keep working) instead of silently truncating edges."""
    n = 9                             # 9 deg-1 nodes -> 9 bucket-0 rows
    indptr = np.arange(n + 1, dtype=np.int64)
    indices = np.zeros(n, np.int32)
    weights = np.ones(n, np.float32)
    with pytest.raises(ELLCapacityError, match="bucket 0 .*9 rows exceed"):
        build_ell(indptr, indices, weights, row_capacity=(8, 8, 8))
    assert issubclass(ELLCapacityError, ValueError)


@given(seed=st.integers(0, 100))
@settings(max_examples=10)
def test_ell_from_coo_fixed_capacity_never_overflows(seed):
    """The worst-case capacities of ``fixed_capacity=True`` hold for any COO
    with the declared (rows, E) envelope — including heavy rows that split
    into many max-bucket chunks — and the aggregation stays exact."""
    r = np.random.default_rng(seed)
    n, e = 48, 600
    hub = int(r.integers(0, n))
    dst = np.where(r.random(e) < 0.5, hub, r.integers(0, n, e))  # heavy row
    src = r.integers(0, n, e)
    w = r.random(e).astype(np.float32)
    g = ell_from_coo(src, dst, w, n)   # must not raise ELLCapacityError
    h = r.normal(size=(n, 8)).astype(np.float32)
    out = np.asarray(bucketed_spmm(g, jnp.asarray(h)))
    ref_out = np.zeros((n, 8), np.float32)
    np.add.at(ref_out, dst, w[:, None] * h[src])
    np.testing.assert_allclose(out, ref_out, rtol=2e-4, atol=1e-5)


# ------------------------------------------------------------- gradient paths
def test_grad_bucketed_spmm_matches_oracle():
    """jax.grad through the kernel (custom VJP = transposed-graph SpMM)
    matches the jnp segment-sum oracle's gradient to 1e-5.

    Moderate degrees: at paper-scale degrees f32 summation-order noise alone
    exceeds 1e-5 (the adjoint property test above covers the heavy buckets).
    """
    indptr, indices, weights = _random_csr(3, heavy=False)
    n = indptr.shape[0] - 1
    g = build_ell(indptr, indices, weights, block_rows=64)
    rng = np.random.default_rng(0)
    h = jnp.asarray(rng.normal(size=(n, 20)).astype(np.float32))
    ptr, ind, w = (jnp.asarray(indptr), jnp.asarray(indices),
                   jnp.asarray(weights))
    f_k = lambda h_: jnp.sum(jnp.sin(bucketed_spmm(g, h_)))
    f_r = lambda h_: jnp.sum(jnp.sin(degree_bucket_spmm_ref(ptr, ind, w, h_)))
    np.testing.assert_allclose(float(f_k(h)), float(f_r(h)), rtol=1e-5)
    gk = jax.jit(jax.grad(f_k))(h)
    gr = jax.grad(f_r)(h)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(gr),
                               rtol=1e-5, atol=1e-5)


def test_vjp_bucketed_spmm_weight_cotangent():
    """The SpMM VJP also produces edge-weight cotangents matching the jnp
    ELL oracle (segment-backend parity: edge weights stay differentiable)."""
    indptr, indices, weights = _random_csr(11, heavy=False)
    n = indptr.shape[0] - 1
    g = build_ell(indptr, indices, weights, block_rows=64)
    rng = np.random.default_rng(2)
    h = jnp.asarray(rng.normal(size=(n, 16)).astype(np.float32))
    ct = jnp.asarray(rng.normal(size=(n, 16)).astype(np.float32))

    def oracle(ws, h_):   # pure-jnp replay of the bucketed kernel
        out = jnp.zeros((n + 1, 16), jnp.float32)
        for idx, w, rows in zip(g.bucket_idx, ws, g.bucket_rows):
            out = out.at[rows].add(ell_spmm_ref(idx, w, h_), mode="drop")
        return out[:n]

    _, vjp_k = jax.vjp(lambda ws, h_: bucketed_spmm(
        ELLGraph(g.bucket_idx, ws, g.bucket_rows, n, n, g.transpose), h_),
        g.bucket_w, h)
    _, vjp_r = jax.vjp(oracle, g.bucket_w, h)
    (dw_k, dh_k), (dw_r, dh_r) = vjp_k(ct), vjp_r(ct)
    np.testing.assert_allclose(np.asarray(dh_k), np.asarray(dh_r),
                               rtol=1e-5, atol=1e-5)
    for a, b, rows in zip(dw_k, dw_r, g.bucket_rows):
        real = np.asarray(rows) < n   # padding rows excluded: the oracle's
        np.testing.assert_allclose(    # scatter drops them, the VJP zeroes them
            np.asarray(a)[real], np.asarray(b)[real], rtol=1e-5, atol=1e-5)


def test_grad_lmc_compensate_matches_oracle():
    """Gradients w.r.t. store/beta/fresh/mask match the jnp oracle
    (including the scatter-add store cotangent), at unaligned shapes."""
    rng = np.random.default_rng(1)
    n, m, d = 70, 123, 50
    store = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    gids = jnp.asarray(rng.integers(0, m, n).astype(np.int32))
    beta = jnp.asarray(rng.random(n).astype(np.float32))
    mask = jnp.asarray((rng.random(n) > 0.2).astype(np.float32))
    fresh = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    out_k = lmc_compensate(store, gids, beta, fresh, mask)
    out_r = lmc_compensate_ref(store, gids, beta, fresh, mask)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-6, atol=1e-6)
    f_k = lambda s, b, f, mk: jnp.sum(jnp.cos(lmc_compensate(s, gids, b, f, mk)))
    f_r = lambda s, b, f, mk: jnp.sum(jnp.cos(lmc_compensate_ref(s, gids, b, f, mk)))
    gk = jax.jit(jax.grad(f_k, argnums=(0, 1, 2, 3)))(store, beta, fresh, mask)
    gr = jax.grad(f_r, argnums=(0, 1, 2, 3))(store, beta, fresh, mask)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


def test_grad_requires_transpose_graph():
    indptr, indices, weights = _random_csr(5)
    n = indptr.shape[0] - 1
    g = build_ell(indptr, indices, weights, with_transpose=False)
    h = jnp.ones((n, 8), jnp.float32)
    with pytest.raises(ValueError, match="with_transpose"):
        jax.grad(lambda h_: jnp.sum(bucketed_spmm(g, h_)))(h)


# --------------------------------------------------- compiled-path selection
def test_interpret_autodetect():
    """CPU containers fall back to interpret; TPU gets the compiled path."""
    assert default_interpret() == (jax.default_backend() != "tpu")
    # the default (interpret=None) must run on whatever backend this is
    rng = np.random.default_rng(0)
    idx = jnp.asarray(rng.integers(0, 16, (256, 8)).astype(np.int32))
    w = jnp.asarray(rng.random((256, 8)).astype(np.float32))
    h = jnp.asarray(rng.normal(size=(16, 128)).astype(np.float32))
    out = ell_spmm(idx, w, h)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ell_spmm_ref(idx, w, h)),
                               rtol=2e-5, atol=2e-5)


def test_compiled_path_lowers_and_compiles():
    """interpret=False must lower + compile (TPU-only: Mosaic cannot lower on
    CPU — the autodetect covers that case, asserted above). ``stream=False``
    pins the legacy resident-block path here; the streamed (default) compile
    check lives in tests/test_streaming.py."""
    if jax.default_backend() != "tpu":
        pytest.skip("no TPU in this container; compiled Mosaic lowering "
                    "requires a TPU backend")
    rng = np.random.default_rng(0)
    idx = jnp.asarray(rng.integers(0, 512, (256, 8)).astype(np.int32))
    w = jnp.asarray(rng.random((256, 8)).astype(np.float32))
    h = jnp.asarray(rng.normal(size=(512, 128)).astype(np.float32))
    jax.jit(lambda a, b, c: ell_spmm(a, b, c, interpret=False,
                                     stream=False)).lower(
        idx, w, h).compile()
    store = jnp.asarray(rng.normal(size=(512, 128)).astype(np.float32))
    gids = jnp.asarray(rng.integers(0, 512, 256).astype(np.int32))
    beta = jnp.asarray(rng.random(256).astype(np.float32))
    mask = jnp.asarray((rng.random(256) > 0.5).astype(np.float32))
    fresh = jnp.asarray(rng.normal(size=(256, 128)).astype(np.float32))
    jax.jit(lambda *a: lmc_compensate(*a, interpret=False,
                                      stream=False)).lower(
        store, gids, beta, fresh, mask).compile()


@pytest.mark.slow
def test_ell_spmm_wide_bucket_sweep():
    """Full-width (K=128) bucket sweep — heavy in interpret mode."""
    rng = np.random.default_rng(0)
    for m, d in ((300, 128), (1000, 256)):
        idx = rng.integers(0, m, (256, 128)).astype(np.int32)
        w = (rng.random((256, 128)) * (rng.random((256, 128)) > 0.5)
             ).astype(np.float32)
        h = rng.normal(size=(m, d)).astype(np.float32)
        out = ell_spmm(jnp.asarray(idx), jnp.asarray(w), jnp.asarray(h))
        ref = ell_spmm_ref(jnp.asarray(idx), jnp.asarray(w), jnp.asarray(h))
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)


def test_gnn_forward_with_kernel_aggregate(small_graph):
    """Swapping the jnp aggregation for the Pallas kernel is output-identical."""
    from repro.core import from_graph
    from repro.models import make_gnn
    g = small_graph
    data = from_graph(g)
    row = np.repeat(np.arange(g.num_nodes), np.diff(g.indptr))
    ws = g.gcn_edge_weights(g.indices.astype(np.int64), row)
    ell = build_ell(g.indptr, g.indices, ws)

    gnn_ref = make_gnn("gcn", g.feature_dim, 32, g.num_classes, 2)
    gnn_krn = make_gnn("gcn", g.feature_dim, 32, g.num_classes, 2,
                       aggregate=ell_aggregate_fn(ell))
    params = gnn_ref.init_params(jax.random.key(0))
    out_ref = gnn_ref.full_forward(params, data.x, data.edges, data.self_w)
    out_krn = gnn_krn.full_forward(params, data.x, data.edges, data.self_w)
    np.testing.assert_allclose(np.asarray(out_krn), np.asarray(out_ref),
                               rtol=2e-3, atol=2e-3)
