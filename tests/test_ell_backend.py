"""The "ell" aggregation backend: the Pallas kernel train step must be a
drop-in for the jnp segment-sum step — same loss, same grads, same store
updates — with every batch of a sampler hitting one jit trace."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (GAS, LMC, from_graph, init_history, make_train_step,
                        to_device_batch)
from repro.graph import ClusterSampler
from repro.graph.structure import Graph
from repro.models import make_gnn


@pytest.fixture(scope="module")
def tiny_graph():
    rng = np.random.default_rng(0)
    n, e = 300, 1200
    x = rng.normal(size=(n, 12)).astype(np.float32)
    y = rng.integers(0, 5, n).astype(np.int32)
    tm = rng.random(n) < 0.6
    vm = (~tm) & (rng.random(n) < 0.5)
    return Graph.from_edges(n, rng.integers(0, n, e), rng.integers(0, n, e),
                            x, y, tm, vm, ~(tm | vm))


@pytest.fixture(scope="module")
def tiny_parts(tiny_graph):
    rng = np.random.default_rng(1)
    return rng.integers(0, 4, tiny_graph.num_nodes).astype(np.int32)


@pytest.mark.parametrize("method", [LMC, GAS], ids=lambda m: m.name)
def test_ell_step_matches_segment(method, tiny_graph, tiny_parts):
    g = tiny_graph
    data = from_graph(g)
    gnn = make_gnn("gcn", g.feature_dim, 16, g.num_classes, 2)
    params = gnn.init_params(jax.random.key(0))
    s = ClusterSampler(g, 4, 1, parts=tiny_parts, seed=0,
                       include_halo=method.include_halo,
                       edge_weight_mode=method.edge_weight_mode)
    step_seg = jax.jit(make_train_step(gnn, method, g.num_nodes))
    step_ell = jax.jit(make_train_step(gnn, method, g.num_nodes,
                                       backend="ell"))
    st_seg = st_ell = init_history(2, g.num_nodes, 16)
    for _ in range(2):   # chained steps: store updates feed the next batch
        sg = s.sample()
        l1, g1, st_seg, _ = step_seg(params, st_seg, to_device_batch(sg),
                                     data.x, data.self_w)
        l2, g2, st_ell, _ = step_ell(params, st_ell,
                                     to_device_batch(sg, backend="ell"),
                                     data.x, data.self_w)
        np.testing.assert_allclose(float(l1), float(l2), rtol=1e-4)
        for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=1e-6)
        np.testing.assert_allclose(np.asarray(st_seg.h), np.asarray(st_ell.h),
                                   rtol=2e-4, atol=1e-6)
        np.testing.assert_allclose(np.asarray(st_seg.v), np.asarray(st_ell.v),
                                   rtol=2e-4, atol=1e-6)


def test_ell_batches_share_one_trace(tiny_graph, tiny_parts):
    """Fixed per-bucket capacities: every batch of a sampler has identical
    ELL shapes, so the jit'd step compiles exactly once per sampler."""
    s = ClusterSampler(tiny_graph, 4, 1, parts=tiny_parts, seed=0)
    shapes = []
    for _ in range(3):
        b = to_device_batch(s.sample(), backend="ell")
        shapes.append(jax.tree.map(lambda x: jnp.shape(x), b))
    assert shapes[0] == shapes[1] == shapes[2]


def test_ell_step_requires_ell_batch(tiny_graph, tiny_parts):
    g = tiny_graph
    data = from_graph(g)
    gnn = make_gnn("gcn", g.feature_dim, 16, g.num_classes, 2)
    params = gnn.init_params(jax.random.key(0))
    s = ClusterSampler(g, 4, 1, parts=tiny_parts, seed=0)
    step = make_train_step(gnn, LMC, g.num_nodes, backend="ell")
    store = init_history(2, g.num_nodes, 16)
    with pytest.raises(ValueError, match="batch.ell"):
        step(params, store, to_device_batch(s.sample()), data.x, data.self_w)
