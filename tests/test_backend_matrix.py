"""Cross-backend correctness matrix: segment vs ell vs ti (ISSUE 9).

The three aggregation/compensation backends of ``make_train_step`` must be
interchangeable gradient estimators:

  * full-batch exactness — with the whole graph as one batch there is nothing
    to compensate, so every (backend, fwd_mode, bwd_mode, stream) combination
    must reduce to ``jax.grad`` exactly;
  * Fig. 3 bias ordering — the store-free message-invariance estimator
    (backend="ti", DESIGN.md §11) must land in LMC's bias regime and beat
    Cluster-GCN's dropped-halo estimate against the exact backward-SGD oracle;
  * trajectory agreement — 50 SGD steps under ti and ell track each other;
  * store traffic — the ti step provably never reads the historical store
    (NaN-poisoned store changes nothing; the store jaxpr invars are dead) and
    ``store_writes=False`` methods never write it.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (LMC, METHODS, MBMethod, TI, backward_sgd_grads,
                        exact_layer_values, from_graph, full_grads,
                        init_history, make_train_step, to_device_batch)
from repro.core.lmc import AGG_BACKENDS
from repro.graph import ClusterSampler
from repro.graph.structure import Graph
from repro.models import make_gnn


def _rel(ga, gb):
    f1 = jax.tree.leaves(ga)
    f2 = jax.tree.leaves(gb)
    num = sum(float(jnp.sum((a - b) ** 2)) for a, b in zip(f1, f2))
    den = sum(float(jnp.sum(jnp.asarray(b) ** 2)) for b in f2)
    return (num / max(den, 1e-12)) ** 0.5


@pytest.fixture(scope="module")
def tiny_graph():
    rng = np.random.default_rng(0)
    n, e = 300, 1200
    x = rng.normal(size=(n, 12)).astype(np.float32)
    y = rng.integers(0, 5, n).astype(np.int32)
    tm = rng.random(n) < 0.6
    vm = (~tm) & (rng.random(n) < 0.5)
    return Graph.from_edges(n, rng.integers(0, n, e), rng.integers(0, n, e),
                            x, y, tm, vm, ~(tm | vm))


@pytest.fixture(scope="module")
def tiny_setup(tiny_graph):
    g = tiny_graph
    data = from_graph(g)
    gnn = make_gnn("gcn", g.feature_dim, 16, g.num_classes, 2)
    params = gnn.init_params(jax.random.key(0))
    loss_ref, grads_ref = full_grads(gnn, params, data)
    s = ClusterSampler(g, 1, 1, parts=np.zeros(g.num_nodes, np.int32))
    sg = s.sample()
    assert sg.n_halo_real == 0
    batches = {b: to_device_batch(sg, backend=b) for b in AGG_BACKENDS}
    return g, data, gnn, params, float(loss_ref), grads_ref, batches


# --------------------------------------------- (a) full-batch == jax.grad
_STREAMS = {"segment": [None], "ell": [None, False], "ti": [None, False]}
_MATRIX = [(bk, f, b, st)
           for bk in AGG_BACKENDS
           for f in ("lmc", "historical", "fresh", "none")
           for b in ("lmc", "none", "fresh")
           for st in _STREAMS[bk]]


@pytest.mark.parametrize(
    "backend,fwd_mode,bwd_mode,stream", _MATRIX,
    ids=[f"{bk}-f_{f}-b_{b}-s_{st}" for bk, f, b, st in _MATRIX])
def test_full_batch_matrix_reduces_to_autodiff(backend, fwd_mode, bwd_mode,
                                               stream, tiny_setup):
    """Whole graph in one batch => no halo => every combination is exact.

    Steps run unjitted: the 60-combination product would otherwise pay one
    XLA compilation each for identical numerics.
    """
    g, data, gnn, params, loss_ref, grads_ref, batches = tiny_setup
    m = MBMethod("matrix", fwd_mode=fwd_mode, bwd_mode=bwd_mode,
                 store_writes=(backend != "ti"))
    step = make_train_step(gnn, m, g.num_nodes, backend=backend,
                           stream=stream)
    store = init_history(gnn.num_layers, g.num_nodes, 16)
    loss, grads, _, _ = step(params, store, batches[backend], data.x,
                             data.self_w)
    assert abs(float(loss) - loss_ref) < 1e-5
    for a, b in zip(jax.tree.leaves(grads), jax.tree.leaves(grads_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-4, atol=1e-6)


# --------------------------------------------- (b) Fig. 3 bias ordering + ti
def test_bias_ordering_ti_in_lmc_regime(small_graph, small_parts):
    """bias(ti) ≈ bias(LMC) < bias(Cluster) vs the exact backward-SGD oracle
    (the Fig. 3 harness of test_lmc_core, extended with the ti backend)."""
    g = small_graph
    data = from_graph(g)
    gnn = make_gnn("gcn", g.feature_dim, 32, g.num_classes, 3)
    params = gnn.init_params(jax.random.key(0))
    hs, vs = exact_layer_values(gnn, params, data)
    biases = {}
    for name, backend in (("lmc", "segment"), ("ti", "ti"),
                          ("cluster", "segment")):
        m = METHODS[name]
        s = ClusterSampler(g, 16, 2, parts=small_parts, seed=1,
                           include_halo=m.include_halo,
                           edge_weight_mode=m.edge_weight_mode,
                           stochastic=False)
        step = jax.jit(make_train_step(gnn, m, g.num_nodes, backend=backend))
        store = init_history(gnn.num_layers, g.num_nodes, 32)
        for _ in range(3):   # warm the store (no-op for the store-free ti)
            for sg in s.epoch():
                _, _, store, _ = step(params, store,
                                      to_device_batch(sg, backend=backend),
                                      data.x, data.self_w)
        errs = []
        for sg in s.epoch():
            _, gm, store, _ = step(params, store,
                                   to_device_batch(sg, backend=backend),
                                   data.x, data.self_w)
            nodes = jnp.asarray(sg.batch_gids[sg.batch_mask > 0])
            gsgd = backward_sgd_grads(gnn, params, data, hs, vs, nodes,
                                      scale=8.0)
            errs.append(_rel(gm["layers"], gsgd))
        biases[name] = float(np.mean(errs))
    # ti must clearly beat the uncompensated estimator and land within a
    # small constant of warmed-store LMC (it trades store reads for the
    # message-invariance approximation, so some headroom is expected)
    assert biases["ti"] < 0.5 * biases["cluster"], biases
    assert biases["ti"] < 4.0 * biases["lmc"], biases


# --------------------------------------------- (c) 50-step loss trajectories
def _run_trajectory(g, data, gnn, method, backend, small_parts, steps=50):
    params = gnn.init_params(jax.random.key(0))
    s = ClusterSampler(g, 16, 2, parts=small_parts, seed=1,
                       stochastic=False)
    step = jax.jit(make_train_step(gnn, method, g.num_nodes, backend=backend))
    store = init_history(gnn.num_layers, g.num_nodes, gnn.hidden_dim)
    losses, i = [], 0
    while len(losses) < steps:
        for sg in s.epoch():
            if len(losses) >= steps:
                break
            loss, grads, store, _ = step(params, store,
                                         to_device_batch(sg, backend=backend),
                                         data.x, data.self_w)
            params = jax.tree.map(lambda p, gr: p - 0.2 * gr, params, grads)
            losses.append(float(loss))
            i += 1
    return np.asarray(losses)


def test_ti_and_ell_loss_trajectories_agree(small_graph, small_parts):
    """50 SGD steps: the store-free ti estimator follows the ell (historical
    compensation) trajectory — same descent, close terminal loss."""
    g = small_graph
    data = from_graph(g)
    gnn = make_gnn("gcn", g.feature_dim, 32, g.num_classes, 2)
    tr_ell = _run_trajectory(g, data, gnn, LMC, "ell", small_parts)
    tr_ti = _run_trajectory(g, data, gnn, TI, "ti", small_parts)
    assert tr_ell[-5:].mean() < 0.85 * tr_ell[:5].mean()  # both actually train
    assert tr_ti[-5:].mean() < 0.85 * tr_ti[:5].mean()
    # terminal losses agree within tolerance
    tail_gap = abs(tr_ti[-10:].mean() - tr_ell[-10:].mean()) \
        / tr_ell[-10:].mean()
    assert tail_gap < 0.10, (tail_gap, tr_ell[-10:].mean(), tr_ti[-10:].mean())
    # trajectories stay close pointwise on average, not just at the end
    rel = np.abs(tr_ti - tr_ell) / np.abs(tr_ell)
    assert float(rel.mean()) < 0.10, float(rel.mean())


# --------------------------------------------- (d) zero store reads / writes
def test_ti_step_never_reads_the_store(tiny_graph):
    """Functional + structural proof of zero historical-store reads.

    Functional: a NaN-poisoned store yields bit-identical loss/grads to a
    zero store. Structural: in the step's jaxpr the store input vars feed no
    equation — they only pass through to the output untouched.
    """
    g = tiny_graph
    data = from_graph(g)
    gnn = make_gnn("gcn", g.feature_dim, 16, g.num_classes, 2)
    params = gnn.init_params(jax.random.key(0))
    rng = np.random.default_rng(1)
    parts = rng.integers(0, 4, g.num_nodes).astype(np.int32)
    s = ClusterSampler(g, 4, 1, parts=parts, seed=0)
    sg = s.sample()
    assert sg.n_halo_real > 0        # the compensation path is actually live
    batch = to_device_batch(sg, backend="ti")
    step = make_train_step(gnn, TI, g.num_nodes, backend="ti")

    store0 = init_history(2, g.num_nodes, 16)
    store_nan = jax.tree.map(lambda x: jnp.full_like(x, jnp.nan), store0)
    l0, g0, out0, _ = step(params, store0, batch, data.x, data.self_w)
    l1, g1, out1, _ = step(params, store_nan, batch, data.x, data.self_w)
    assert float(l0) == float(l1) and np.isfinite(float(l0))
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # store_writes=False: the store rides through bit-identical (NaNs intact)
    for a, b in zip(jax.tree.leaves(store_nan), jax.tree.leaves(out1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    closed = jax.make_jaxpr(step)(params, store0, batch, data.x, data.self_w)
    flat, _ = jax.tree_util.tree_flatten(
        (params, store0, batch, data.x, data.self_w))
    store_leaves = jax.tree_util.tree_leaves(store0)
    store_vars = {id(closed.jaxpr.invars[i]) for i, a in enumerate(flat)
                  if any(a is sl for sl in store_leaves)}
    assert len(store_vars) == len(store_leaves)
    used = {id(v) for eqn in closed.jaxpr.eqns for v in eqn.invars
            if not isinstance(v, jax.core.Literal)}
    assert not (store_vars & used), "ti step consumed a store input"


def test_store_writes_gating_is_orthogonal_to_backend(tiny_graph):
    """``store_writes=False`` freezes the store under any backend (here: ell,
    which *reads* it), while a store-writing method on backend="ti" refreshes
    batch rows without its gradients ever depending on the store."""
    g = tiny_graph
    data = from_graph(g)
    gnn = make_gnn("gcn", g.feature_dim, 16, g.num_classes, 2)
    params = gnn.init_params(jax.random.key(0))
    rng = np.random.default_rng(1)
    parts = rng.integers(0, 4, g.num_nodes).astype(np.int32)
    s = ClusterSampler(g, 4, 1, parts=parts, seed=0)
    sg = s.sample()
    store = init_history(2, g.num_nodes, 16)

    frozen = MBMethod("lmc_frozen", fwd_mode="lmc", bwd_mode="lmc",
                      store_writes=False)
    step = make_train_step(gnn, frozen, g.num_nodes, backend="ell")
    _, _, out, _ = step(params, store, to_device_batch(sg, backend="ell"),
                        data.x, data.self_w)
    for a, b in zip(jax.tree.leaves(store), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    batch = to_device_batch(sg, backend="ti")
    step_w = make_train_step(gnn, LMC, g.num_nodes, backend="ti")
    step_ro = make_train_step(gnn, TI, g.num_nodes, backend="ti")
    _, gw, out_w, _ = step_w(params, store, batch, data.x, data.self_w)
    _, gro, _, _ = step_ro(params, store, batch, data.x, data.self_w)
    changed = np.where(np.any(np.asarray(out_w.h[0]) != 0, axis=-1))[0]
    in_batch = set(sg.batch_gids[sg.batch_mask > 0].tolist())
    assert len(changed) and set(changed.tolist()) <= in_batch
    for a, b in zip(jax.tree.leaves(gw), jax.tree.leaves(gro)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_backend_requires_its_batch_fields(tiny_graph):
    g = tiny_graph
    data = from_graph(g)
    gnn = make_gnn("gcn", g.feature_dim, 16, g.num_classes, 2)
    params = gnn.init_params(jax.random.key(0))
    s = ClusterSampler(g, 1, 1, parts=np.zeros(g.num_nodes, np.int32))
    sg = s.sample()
    store = init_history(2, g.num_nodes, 16)
    step = make_train_step(gnn, TI, g.num_nodes, backend="ti")
    with pytest.raises(ValueError, match="batch.ell"):
        step(params, store, to_device_batch(sg), data.x, data.self_w)
    ell_only = to_device_batch(sg, backend="ell")
    with pytest.raises(ValueError, match="batch.ti_scale"):
        step(params, store, ell_only, data.x, data.self_w)
