"""Serving tier (DESIGN.md §12): store-backed batched inference + robustness.

Two layers of coverage:

  * unit tests for the pieces — gateway pad solving, the circuit-breaker FSM,
    the crc row ledger, config validation, the shared ρ-budget constant;
  * the serving fault matrix (``-k matrix``): every serving fault class
    (hung batch, poisoned store rows, queue-overflow burst, worker crash)
    must produce typed/degraded responses — never a hang, a crash, or a
    silent wrong answer — and leave the server healthy afterward.

The headline correctness property: with an exact store, the exact serving
rung answers identically to the full-graph forward.
"""
import numpy as np
import pytest

import jax

from repro.core import RHO_BUDGET_DEFAULT
from repro.core.exact import from_graph
from repro.models import make_gnn
from repro.serve import (GNNServer, CircuitBreaker, RequestTooLarge,
                         ServeConfig, StoreGateway, StoreIntegrity,
                         warm_store)
from repro.serve.gateway import request_pads
from repro.train.health import FaultPlan


@pytest.fixture(scope="module")
def setup(small_graph):
    """Shared (gnn, params, data, exact store) — servers reuse the store."""
    g = small_graph
    gnn = make_gnn("gcn", g.feature_dim, 32, g.num_classes, 3)
    params = gnn.init_params(jax.random.key(0))
    data = from_graph(g)
    store = warm_store(gnn, params, data)
    return gnn, params, data, store


def _server(small_graph, setup, **cfg_kw):
    gnn, params, data, store = setup
    plan = cfg_kw.pop("fault_plan", None)
    cfg = ServeConfig(**{"default_deadline_s": 30.0, **cfg_kw})
    return GNNServer(gnn, small_graph, params, store=store, config=cfg,
                     fault_plan=plan, data=data)


@pytest.fixture()
def srv(small_graph, setup):
    s = _server(small_graph, setup)
    yield s
    s.close(drain=False, timeout=30.0)


# ------------------------------------------------------------------ gateway
def test_request_pads_bounds(small_graph):
    g = small_graph
    pad_halo, pad_edges = request_pads(g, 8)
    assert 0 < pad_halo <= g.num_nodes
    assert 0 < pad_edges <= g.num_edges
    # larger buckets need at-least-as-large pads
    ph32, pe32 = request_pads(g, 32)
    assert ph32 >= pad_halo and pe32 >= pad_edges


def test_bucket_for(small_graph):
    gw = StoreGateway(small_graph, buckets=(8, 32, 128))
    assert gw.bucket_for(1) == 8
    assert gw.bucket_for(8) == 8
    assert gw.bucket_for(9) == 32
    assert gw.bucket_for(128) == 128
    with pytest.raises(RequestTooLarge):
        gw.bucket_for(129)


def test_gateway_build_padded_shapes(small_graph):
    gw = StoreGateway(small_graph, buckets=(8, 32, 128))
    targets = np.array([3, 77, 500, 1999, 42])
    sg, hb = gw.build(targets)
    assert sg.n_batch == 8 and sg.n_batch_real == 5
    np.testing.assert_array_equal(np.asarray(sg.batch_gids)[:5], targets)
    # same bucket → same shapes → one compiled trace for any 1..8 targets
    sg2, _ = gw.build(np.array([9]))
    assert np.asarray(sg2.halo_gids).shape == np.asarray(sg.halo_gids).shape
    assert np.asarray(sg2.edge_dst).shape == np.asarray(sg.edge_dst).shape


# ------------------------------------------------------------- policy units
def test_circuit_breaker_fsm():
    br = CircuitBreaker(heal_after=2, cooldown=2)
    assert br.state == "closed" and br.allow_exact(1)
    br.record_failure(5)
    assert br.state == "open"
    assert not br.allow_exact(6) and not br.allow_exact(7)
    assert br.allow_exact(8) and br.state == "half-open"
    br.record_success()
    assert br.state == "half-open"     # needs heal_after consecutive
    br.record_success()
    assert br.state == "closed"
    # failure while probing re-opens
    br.record_failure(9)
    assert br.allow_exact(12) and br.state == "half-open"
    br.record_failure(12)
    assert br.state == "open" and not br.allow_exact(13)


def test_store_integrity_detects_mutation():
    rows = np.arange(2 * 3 * 4, dtype=np.float32).reshape(2, 3, 4)
    gids = np.array([10, 20, 30])
    ledger = StoreIntegrity(num_layers=2, num_nodes=64)
    ledger.record(gids, rows)
    assert ledger.verify(gids, rows).size == 0
    bad = rows.copy()
    bad[1, 2, 0] += 1.0                # flip one value of (layer 1, gid 30)
    np.testing.assert_array_equal(ledger.verify(gids, bad), [30])


@pytest.mark.parametrize("kw", [
    {"buckets": (32, 8)}, {"buckets": ()}, {"queue_depth": 0},
    {"max_attempts": 0}, {"backend": "coo"}, {"ti_fwd_mode": "fresh"},
    {"force_mode": "fast"}, {"rho_budget": 0},
])
def test_serve_config_validate(kw):
    with pytest.raises(ValueError):
        ServeConfig(**kw).validate()


def test_rho_budget_single_definition():
    """Satellite: one ρ-budget constant shared by training and serving."""
    from repro.core.methods import RHO_BUDGET_DEFAULT as core_rho
    from repro.train.health import RHO_BUDGET_DEFAULT as train_rho
    assert core_rho is train_rho is RHO_BUDGET_DEFAULT
    assert ServeConfig().rho_budget == RHO_BUDGET_DEFAULT


# ------------------------------------------------------------ serving paths
def test_exact_parity_with_full_forward(small_graph, setup, srv):
    """Exact store + exact rung == full-graph forward, to float precision."""
    gnn, params, data, _ = setup
    full = np.asarray(
        gnn.full_forward(params, data.x, data.edges, data.self_w))
    srv.config.return_logits = True
    nodes = np.array([0, 17, 999, 2047, 512])
    r = srv.infer(nodes)
    assert r.status == "ok" and r.mode == "exact"
    np.testing.assert_allclose(r.logits, full[nodes], atol=1e-4)
    np.testing.assert_array_equal(r.classes, full[nodes].argmax(-1))


def test_submit_rejects_malformed(small_graph, srv):
    n = small_graph.num_nodes
    assert srv.infer(np.array([], dtype=np.int64)).status == "error"
    assert srv.infer(np.array([-1])).status == "error"
    assert srv.infer(np.array([n])).status == "error"
    r = srv.infer(np.arange(129))
    assert r.status == "too-large"
    with pytest.raises(Exception):
        r.raise_for_status()


def test_duplicate_targets_align(srv):
    r = srv.infer(np.array([5, 5, 9]))
    assert r.status == "ok" and r.classes.shape == (3,)
    assert r.classes[0] == r.classes[1]


def test_exact_serve_refreshes_staleness(small_graph, setup):
    s = _server(small_graph, setup)
    try:
        s.notify_update(3)             # trainer moved params 3 steps
        nodes = np.array([1, 2, 3])
        assert s.infer(nodes).status == "ok"
        assert s._guard.staleness[:, nodes].max() == 0   # refreshed
        assert s._guard.staleness[:, 2000].max() == 3    # untouched rows age
    finally:
        s.close(drain=False)


def test_staleness_degrades_then_repair_heals(small_graph, setup):
    s = _server(small_graph, setup)
    try:
        s.notify_update(RHO_BUDGET_DEFAULT + 1)  # every row over budget
        nodes = np.array([10, 11])
        r = s.infer(nodes)
        assert r.status == "degraded" and r.mode == "ti"
        assert "staleness" in r.degraded_reason
        # repair reset the offending halo rows → same request is exact again
        # (the worker is serial: repair finishes before the next batch runs)
        r2 = s.infer(nodes)
        assert r2.status == "ok" and r2.mode == "exact"
        assert any(e["kind"] == "repair" for e in s.events)
    finally:
        s.close(drain=False)


def test_drain_completes_inflight(small_graph, setup):
    s = _server(small_graph, setup)
    futs = [s.submit(np.array([i, i + 100])) for i in range(10)]
    assert s.drain(timeout=120.0)
    responses = [f.result(timeout=1.0) for f in futs]   # already resolved
    assert all(r.status == "ok" for r in responses)
    assert s.stats()["pending"] == 0


def test_close_without_drain_resolves_everything(small_graph, setup):
    s = _server(small_graph, setup)
    futs = [s.submit(np.array([i])) for i in range(20)]
    assert s.close(drain=False, timeout=120.0)
    statuses = {f.result(timeout=1.0).status for f in futs}
    assert statuses <= {"ok", "closed"}
    assert s.stats()["pending"] == 0
    assert s.submit(np.array([0])).result(timeout=1.0).status == "closed"


def test_breaker_trips_on_nan_and_heals(small_graph, setup):
    """verify_rows off → poisoned rows reach the exact forward → NaN output
    trips the breaker; repair + probes close it again."""
    plan = FaultPlan(serve_poison_at=(2,))
    s = _server(small_graph, setup, verify_rows=False,
                breaker_cooldown=1, breaker_heal_after=1, fault_plan=plan)
    try:
        nodes = np.array([4, 5, 6])
        assert s.infer(nodes).status == "ok"            # seq 1
        r = s.infer(nodes)                              # seq 2: poisoned
        assert r.status == "degraded" and r.degraded_reason == "nan-circuit"
        assert np.isfinite(np.asarray(r.classes)).all()
        assert s.stats()["breaker"] == "open"
        r3 = s.infer(nodes)                             # seq 3: cooling down
        assert r3.status == "degraded"
        assert r3.degraded_reason == "nan-circuit-open"
        r4 = s.infer(nodes)                             # seq 4: probe heals
        assert r4.status == "ok" and s.stats()["breaker"] == "closed"
        kinds = [e["kind"] for e in s.events]
        assert "breaker-open" in kinds and "breaker-closed" in kinds
        assert "repair" in kinds
    finally:
        s.close(drain=False)


# ------------------------------------------------------ serving fault matrix
def test_matrix_serve_hung_batch(small_graph, setup):
    """A stalled batch becomes typed timeouts, never a hang; the server
    serves the next request normally."""
    plan = FaultPlan(serve_slow_at=(2,), serve_slow_s=0.6)
    s = _server(small_graph, setup, fault_plan=plan)
    try:
        assert s.infer(np.array([1])).status == "ok"    # warms the trace
        r = s.infer(np.array([2]), deadline_s=0.3)      # seq 2: stalled
        assert r.status == "timeout"
        assert s.infer(np.array([3])).status == "ok"
        assert any(e["kind"] == "slow-batch" for e in s.events)
        st = s.stats()
        assert st["pending"] == 0 and st["breaker"] == "closed"
    finally:
        s.close(drain=False)


def test_matrix_serve_poisoned_store_rows(small_graph, setup):
    """crc verification catches poisoned rows before they reach the forward:
    the answer degrades to the store-free rung and repair heals the rows."""
    plan = FaultPlan(serve_poison_at=(2,))
    s = _server(small_graph, setup, fault_plan=plan)
    try:
        nodes = np.array([7, 8, 9])
        assert s.infer(nodes).status == "ok"
        r = s.infer(nodes)                              # seq 2: poisoned
        assert r.status == "degraded" and r.mode == "ti"
        assert "store-corrupt" in r.degraded_reason
        assert np.isfinite(np.asarray(r.classes)).all()  # no silent NaN
        r3 = s.infer(nodes)                             # healed
        assert r3.status == "ok" and r3.mode == "exact"
        assert any(e["kind"] == "repair" for e in s.events)
        assert np.isfinite(np.asarray(jax.device_get(s.store.h))).all()
    finally:
        s.close(drain=False)


def test_matrix_serve_worker_crash(small_graph, setup):
    """An injected worker crash retries in place within the attempt budget
    and still answers; the crash is visible in counters, not to the caller."""
    plan = FaultPlan(serve_crash_at=(1,))
    s = _server(small_graph, setup, fault_plan=plan)
    try:
        r = s.infer(np.array([12, 13]))
        assert r.status == "ok" and r.attempts == 2
        st = s.stats()
        assert st["worker_restarts"] == 1 and st["pending"] == 0
        assert s.infer(np.array([14])).status == "ok"
    finally:
        s.close(drain=False)


def test_matrix_serve_worker_crash_budget_exhausted(small_graph, setup):
    """Crashes past the retry budget end in a typed error — not a hang —
    and the worker survives to serve the next request."""
    plan = FaultPlan(serve_crash_at=(1, 2))
    s = _server(small_graph, setup, max_attempts=1, fault_plan=plan)
    try:
        r = s.infer(np.array([20]))
        assert r.status == "error" and "retry budget" in r.detail
        r2 = s.infer(np.array([21]))                    # seq 2 crashes too
        assert r2.status == "error"
        assert s.infer(np.array([22])).status == "ok"   # healthy again
        assert s.stats()["pending"] == 0
    finally:
        s.close(drain=False)


def test_matrix_serve_queue_overflow_burst(small_graph, setup):
    """A burst beyond queue_depth sheds with typed Overloaded — the queue
    is bounded, admission never blocks, and nothing is dropped silently."""
    plan = FaultPlan(serve_slow_at=(2,), serve_slow_s=0.5)
    s = _server(small_graph, setup, queue_depth=4, fault_plan=plan)
    try:
        assert s.infer(np.array([1])).status == "ok"    # warm trace
        futs = [s.submit(np.array([2]))]                # seq 2: stalls
        import time
        time.sleep(0.1)                                 # worker enters stall
        futs += [s.submit(np.array([i])) for i in range(3, 33)]
        responses = [f.result(timeout=120.0) for f in futs]
        statuses = [r.status for r in responses]
        assert statuses.count("overloaded") >= 1        # burst was shed
        assert statuses.count("ok") >= 1                # queued ones answered
        assert set(statuses) <= {"ok", "overloaded"}
        assert s.infer(np.array([40])).status == "ok"
        assert s.stats()["pending"] == 0
    finally:
        s.close(drain=False)
