"""repro.dist.sharding: no-ops off-mesh, correct PartitionSpecs on a fake
8-device mesh (subprocess: device count is locked at jax init), axis sizes on
1D/2D/3D meshes, and the concat_rows partitioner-bug workaround."""
import jax.numpy as jnp
import numpy as np

from _spmd import run_spmd as _run


def test_noops_on_single_device():
    """Off-mesh, every helper is the identity / trivial answer."""
    from repro.dist.sharding import (concat_rows, current_mesh, dp_axis_size,
                                     model_axis_size, shard_act, shard_res)
    assert current_mesh() is None
    assert dp_axis_size() == 1
    assert model_axis_size() == 1
    x = jnp.ones((2, 4, 8))
    assert shard_act(x, "dp", None, "model") is x
    assert shard_res(x) is x
    a, b = jnp.arange(3), jnp.arange(3, 8)
    np.testing.assert_array_equal(np.asarray(concat_rows([a, b])),
                                  np.arange(8))


def test_single_device_mesh_still_noop():
    """A registered size-1 mesh must not insert constraints either."""
    from repro.dist.mesh import make_mesh
    from repro.dist.sharding import activation_sharding, shard_act
    x = jnp.ones((2, 4))
    with activation_sharding(make_mesh((1,), ("data",))):
        assert shard_act(x, "dp", None) is x


def test_partition_specs_on_fake_8_device_mesh():
    out = _run("""
        import jax, jax.numpy as jnp
        import numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.dist.mesh import make_mesh
        from repro.dist.sharding import (activation_sharding, concat_rows,
                                         resolve_spec, shard_act, shard_res)

        mesh = make_mesh((4, 2), ("data", "model"))
        # resolve: dp -> data, model kept when divisible, dropped when not
        assert resolve_spec(mesh, (8, 5, 6), ("dp", None, "model")) == \\
            P("data", None, "model")
        assert resolve_spec(mesh, (8, 5, 7), ("dp", None, "model")) == \\
            P("data", None, None)           # 7 % 2 != 0 -> dropped
        assert resolve_spec(mesh, (6, 3), ("dp", "model")) == P(None, None)
        mesh3 = make_mesh((2, 2, 2), ("pod", "data", "model"))
        assert resolve_spec(mesh3, (8, 4), ("dp", "model")) == \\
            P(("pod", "data"), "model")

        with activation_sharding(mesh):
            out = jax.jit(lambda x: shard_act(x, "dp", None, "model", None))(
                jnp.ones((8, 4, 2, 3)))
            assert out.sharding.spec == P("data", None, "model"), out.sharding
            res = jax.jit(shard_res)(jnp.ones((8, 4, 16)))
            assert res.sharding.spec == P("data", "model"), res.sharding
            # concat_rows: exact values AND row-sharded result (jax 0.4.37
            # miscompiles a plain sharded concatenate on a multi-axis mesh)
            a = jnp.arange(1280, dtype=jnp.int32)
            b = jnp.arange(1280, 5888, dtype=jnp.int32)
            from jax.sharding import NamedSharding
            cat = jax.jit(lambda u, v: concat_rows([u, v]),
                          in_shardings=(NamedSharding(mesh, P("data")),
                                        NamedSharding(mesh, P())))(a, b)
            np.testing.assert_array_equal(np.asarray(cat), np.arange(5888))
            assert cat.sharding.spec == P("data"), cat.sharding
        print("SPECS-OK")
    """)
    assert "SPECS-OK" in out


def test_axis_sizes_on_1d_2d_3d_meshes():
    out = _run("""
        from repro.dist.mesh import make_mesh
        from repro.dist.sharding import (activation_sharding, data_axes,
                                         dp_axis_size, dp_entry,
                                         model_axis_size)

        m1 = make_mesh((8,), ("data",))
        assert dp_axis_size(m1) == 8 and model_axis_size(m1) == 1
        assert data_axes(m1) == ("data",) and dp_entry(m1) == "data"

        m2 = make_mesh((4, 2), ("data", "model"))
        assert dp_axis_size(m2) == 4 and model_axis_size(m2) == 2

        m3 = make_mesh((2, 2, 2), ("pod", "data", "model"))
        assert dp_axis_size(m3) == 4 and model_axis_size(m3) == 2
        assert data_axes(m3) == ("pod", "data")
        assert dp_entry(m3) == ("pod", "data")

        # registry answers without an explicit mesh argument
        with activation_sharding(m3):
            assert dp_axis_size() == 4 and model_axis_size() == 2
        assert dp_axis_size() == 1  # popped cleanly
        print("AXES-OK")
    """)
    assert "AXES-OK" in out


def test_spmd_shardings_derive_from_dist():
    """core.distributed.spmd_shardings rides on the dist factories."""
    out = _run("""
        from jax.sharding import PartitionSpec as P
        from repro.core.distributed import spmd_shardings
        from repro.dist.mesh import make_mesh

        mesh = make_mesh((4, 2), ("data", "model"))
        bsh, ssh, xsh, swsh, psh = spmd_shardings(mesh)
        assert bsh.batch_gids.spec == P("data")
        assert bsh.loss_scale.spec == P()
        assert ssh["h"].spec == P(None, "data", "model")
        assert xsh.spec == P("data", None)
        assert psh.spec == P()

        mesh3 = make_mesh((2, 2, 2), ("pod", "data", "model"))
        bsh, ssh, _, _, _ = spmd_shardings(mesh3)
        assert bsh.batch_gids.spec == P(("pod", "data"))
        assert ssh["v"].spec == P(None, ("pod", "data"), "model")
        print("SPMD-SH-OK")
    """)
    assert "SPMD-SH-OK" in out
