"""Training supervisor (DESIGN.md §10): health guard, hardened checkpoints,
layered fault injection.

The fault-injection matrix tests assert the acceptance contract: every fault
class (preemption, pipeline-worker crash, mid-save checkpoint failure,
NaN batch) recovers without operator intervention, and the post-recovery
loss stream matches an uninterrupted run (exactly for preemption / pipeline
/ checkpoint faults, rtol=1e-6 for NaN rollback-and-retry).
"""
import json
import zlib
from pathlib import Path

import numpy as np
import pytest

from repro.checkpoint import CheckpointError, CheckpointManager
from repro.core import LMC
from repro.graph import ClusterSampler
from repro.models import make_gnn
from repro.optim import sgd
from repro.train import (FaultPlan, GNNTrainer, HealthConfig, HealthGuard,
                         StalenessBudgetError, TrainingDivergedError)


def _trainer(g, parts, tmp, **kw):
    gnn = make_gnn("gcn", g.feature_dim, 32, g.num_classes, 2)
    s = ClusterSampler(g, 16, 2, parts=parts, seed=1)
    return GNNTrainer(gnn, LMC, g, s, sgd(lr=0.3), ckpt_dir=tmp,
                      ckpt_every=10, **kw)


def _losses(tr):
    """step -> loss, keeping the LAST record per step (replays overwrite)."""
    return {h["step"]: h["loss"] for h in tr.history if "loss" in h}


def _events(tr, kind=None):
    evs = [h for h in tr.history if h.get("event")]
    return [e for e in evs if kind is None or e["event"] == kind] \
        if kind else evs


@pytest.fixture(scope="module")
def clean_runs(small_graph, small_parts, tmp_path_factory):
    """Shared uninterrupted baselines: synchronous and pipelined streams."""
    base = tmp_path_factory.mktemp("clean")
    t_sync = _trainer(small_graph, small_parts, str(base / "sync"))
    t_sync.run(40)
    t_pipe = _trainer(small_graph, small_parts, str(base / "pipe"),
                      prefetch=2)
    t_pipe.run(30)
    t_pipe.close()
    return {"sync": _losses(t_sync), "pipe": _losses(t_pipe)}


# ------------------------------------------------------- fault matrix
def test_matrix_preemption(small_graph, small_parts, tmp_path, clean_runs):
    plan = FaultPlan(preempt_at=(25,))
    tr = _trainer(small_graph, small_parts, str(tmp_path),
                  failure_injector=plan)
    tr.run(40)
    evs = _events(tr, "preemption")
    assert len(evs) == 1 and evs[0]["restored"]
    got = _losses(tr)
    ref = clean_runs["sync"]
    np.testing.assert_array_equal([ref[s] for s in sorted(ref)],
                                  [got[s] for s in sorted(got)])


def test_matrix_pipeline_worker_crash(small_graph, small_parts, tmp_path,
                                      clean_runs):
    plan = FaultPlan(pipeline_at=(13,))
    tr = _trainer(small_graph, small_parts, str(tmp_path),
                  failure_injector=plan, prefetch=2)
    tr.run(30)
    tr.close()
    assert len(_events(tr, "pipeline-fault")) == 1
    got = _losses(tr)
    ref = clean_runs["pipe"]
    np.testing.assert_array_equal([ref[s] for s in sorted(ref)],
                                  [got[s] for s in sorted(got)])


def test_matrix_ckpt_write_failure(small_graph, small_parts, tmp_path,
                                   clean_runs):
    plan = FaultPlan(ckpt_write_at=(30,))
    tr = _trainer(small_graph, small_parts, str(tmp_path),
                  failure_injector=plan)
    tr.run(40)
    assert len(_events(tr, "ckpt-write-failed")) == 1
    # the aborted save left no partial/tmp state and older steps survive
    assert 30 not in tr.ckpt.all_steps()
    assert not list(Path(tmp_path).glob("*.tmp.*"))
    assert tr.ckpt.latest_step() == 40
    got = _losses(tr)
    ref = clean_runs["sync"]
    np.testing.assert_array_equal([ref[s] for s in sorted(ref)],
                                  [got[s] for s in sorted(got)])


def test_matrix_nan_batch_rollback(small_graph, small_parts, tmp_path,
                                   clean_runs):
    """Injected NaN gradients -> health rollback -> stream-deterministic
    replay (rtol=1e-6, as in test_resume_is_deterministic)."""
    plan = FaultPlan(nan_batch_at=(25,))
    tr = _trainer(small_graph, small_parts, str(tmp_path),
                  failure_injector=plan, health=HealthConfig())
    tr.run(40)
    evs = _events(tr, "health-rollback")
    assert len(evs) == 1 and "non-finite" in evs[0]["reason"]
    got = _losses(tr)
    ref = clean_runs["sync"]
    np.testing.assert_allclose([ref[s] for s in sorted(ref)],
                               [got[s] for s in sorted(got)], rtol=1e-6)
    # and the run still converges
    losses = [h["loss"] for h in tr.history if "loss" in h]
    assert losses[-1] < losses[0]


# ------------------------------------------------------- health policies
def test_nan_skip_batch_policy(small_graph, small_parts, tmp_path):
    plan = FaultPlan(nan_batch_at=(15,))
    tr = _trainer(small_graph, small_parts, str(tmp_path),
                  failure_injector=plan,
                  health=HealthConfig(policy="skip-batch"))
    tr.run(30)
    evs = _events(tr, "health-skip-batch")
    assert len(evs) == 1
    losses = _losses(tr)
    assert 16 not in losses          # the poisoned step was skipped, not applied
    assert all(np.isfinite(v) for v in losses.values())
    assert losses[max(losses)] < losses[min(losses)]


def test_rollback_without_checkpoint_degrades_to_skip(small_graph,
                                                      small_parts):
    plan = FaultPlan(nan_batch_at=(5,))
    gnn = make_gnn("gcn", small_graph.feature_dim, 32,
                   small_graph.num_classes, 2)
    s = ClusterSampler(small_graph, 16, 2, parts=small_parts, seed=1)
    tr = GNNTrainer(gnn, LMC, small_graph, s, sgd(lr=0.3),
                    failure_injector=plan, health=HealthConfig())  # no ckpt
    tr.run(12)
    evs = _events(tr, "health-skip-batch")
    assert len(evs) == 1 and evs[0]["policy"] == "rollback"
    assert all(np.isfinite(v) for v in _losses(tr).values())


def test_retry_budget_exhausts(small_graph, small_parts):
    """Persistent divergence without recovery aborts instead of live-locking."""
    plan = FaultPlan(nan_batch_at=(3, 4, 5, 6, 7))
    gnn = make_gnn("gcn", small_graph.feature_dim, 32,
                   small_graph.num_classes, 2)
    s = ClusterSampler(small_graph, 16, 2, parts=small_parts, seed=1)
    tr = GNNTrainer(gnn, LMC, small_graph, s, sgd(lr=0.3),
                    failure_injector=plan, health=HealthConfig(),
                    max_retries=2)
    with pytest.raises(TrainingDivergedError):
        tr.run(20)


def test_lr_backoff_on_rollback(small_graph, small_parts, tmp_path):
    plan = FaultPlan(nan_batch_at=(15,))
    tr = _trainer(small_graph, small_parts, str(tmp_path),
                  failure_injector=plan,
                  health=HealthConfig(lr_backoff=0.5))
    tr.run(25)
    assert len(_events(tr, "health-rollback")) == 1
    assert tr.lr == pytest.approx(0.15)   # 0.3 * 0.5
    assert all(np.isfinite(v) for v in _losses(tr).values())


# ------------------------------------------------------- health guard unit
def test_guard_spike_detection():
    g = HealthGuard(HealthConfig(spike_factor=10.0, warmup=4), 2, 8)
    for _ in range(6):
        assert g.check_step(1.0, 0.5) is None
        g.observe(1.0)
    assert g.check_step(1.5, 0.5) is None         # normal fluctuation
    reason = g.check_step(50.0, 0.5)              # 50x the median baseline
    assert reason is not None and "spike" in reason
    assert g.check_step(float("nan"), 0.5) is not None
    assert g.check_step(1.0, float("inf")) is not None


def test_guard_grad_norm_limit():
    g = HealthGuard(HealthConfig(grad_norm_limit=10.0), 2, 8)
    assert g.check_step(1.0, 9.0) is None
    assert "exceeds limit" in g.check_step(1.0, 11.0)


def test_guard_staleness_counters():
    g = HealthGuard(HealthConfig(), num_layers=2, num_nodes=6)
    gids = np.array([0, 1, 2])
    mask = np.ones(3)
    g.tick(gids, mask, store_updated=True)
    assert g.staleness[:, :3].max() == 0 and g.staleness[:, 3:].min() == 1
    g.tick(gids, mask, store_updated=False)       # skip-store straggler step
    assert g.staleness[:, :3].min() == 1 and g.staleness[:, 3:].min() == 2
    halo = np.array([3, 4])
    assert g.halo_staleness(halo, np.ones(2)) == 2
    assert g.halo_staleness(halo, np.zeros(2)) == 0   # fully masked halo
    g.reset_staleness()
    assert g.staleness.max() == 0


def test_guard_rho_budget():
    cfg = HealthConfig(rho_budget=3)
    g = HealthGuard(cfg, 1, 4)
    assert g.check_rho_budget(3) is None
    assert "rho budget" in g.check_rho_budget(4)
    strict = HealthGuard(HealthConfig(rho_budget=3, rho_strict=True), 1, 4)
    with pytest.raises(StalenessBudgetError):
        strict.check_rho_budget(4)


def test_staleness_recorded_in_history(small_graph, small_parts, tmp_path):
    tr = _trainer(small_graph, small_parts, str(tmp_path),
                  health=HealthConfig())
    tr.run(15)
    recs = [h for h in tr.history if "loss" in h]
    assert all("halo_staleness" in h for h in recs)
    assert max(h["halo_staleness"] for h in recs) >= 1  # uniform schedule ages rows


# ------------------------------------------------------- hardened checkpoints
def _tree():
    return {"a": np.arange(10.0), "b": {"c": np.ones((3, 3))}}


def test_corrupt_latest_truncated_leaf_falls_back(tmp_path):
    cm = CheckpointManager(tmp_path, keep=3)
    for s in (10, 20, 30):
        cm.save(s, _tree(), {"step": s})
    f = tmp_path / "step_0000000030" / "arr_0.npy"
    f.write_bytes(f.read_bytes()[:40])            # truncate
    restored, extras, step = cm.restore(_tree())
    assert step == 20 and extras["step"] == 20
    np.testing.assert_array_equal(restored["a"], _tree()["a"])
    assert not cm.verify(30) and cm.verify(20)


def test_corrupt_checksum_falls_back(tmp_path):
    cm = CheckpointManager(tmp_path, keep=3)
    for s in (10, 20):
        cm.save(s, _tree(), {"step": s})
    f = tmp_path / "step_0000000020" / "arr_1.npy"
    raw = bytearray(f.read_bytes())
    raw[-1] ^= 0xFF                               # bit-flip payload, same size
    f.write_bytes(bytes(raw))
    _, _, step = cm.restore(_tree())
    assert step == 10
    with pytest.raises(CheckpointError, match="checksum"):
        cm.restore(_tree(), step=20)


def test_mangled_manifest_falls_back(tmp_path):
    cm = CheckpointManager(tmp_path, keep=3)
    for s in (10, 20):
        cm.save(s, _tree(), {"step": s})
    (tmp_path / "step_0000000020" / "manifest.json").write_text("{not json")
    _, _, step = cm.restore(_tree())
    assert step == 10


def test_missing_leaf_raises_named_error(tmp_path):
    cm = CheckpointManager(tmp_path)
    cm.save(10, _tree(), {"step": 10})
    (tmp_path / "step_0000000010" / "arr_1.npy").unlink()
    with pytest.raises(CheckpointError, match=r"step 10.*arr_1\.npy"):
        cm.restore(_tree(), step=10)


def test_num_leaves_mismatch_raises_clear_error(tmp_path):
    cm = CheckpointManager(tmp_path)
    cm.save(10, _tree(), {"step": 10})
    bigger = {**_tree(), "d": np.zeros(4)}
    with pytest.raises(CheckpointError, match="2 leaves.*expects 3"):
        cm.restore(bigger, step=10)


def test_no_verifiable_checkpoint_raises(tmp_path):
    cm = CheckpointManager(tmp_path)
    cm.save(10, _tree(), {"step": 10})
    f = tmp_path / "step_0000000010" / "arr_0.npy"
    f.write_bytes(f.read_bytes()[:10])
    with pytest.raises(CheckpointError, match="no verifiable checkpoint"):
        cm.restore(_tree())


def test_orphaned_tmp_dir_gc(tmp_path):
    orphan = tmp_path / "step_0000000099.tmp.abc123"
    orphan.mkdir(parents=True)
    (orphan / "arr_0.npy").write_bytes(b"partial")
    cm = CheckpointManager(tmp_path)               # init-time GC
    assert not orphan.exists()
    orphan2 = tmp_path / "step_0000000098.tmp.xyz"
    orphan2.mkdir()
    cm.save(10, _tree(), {"step": 10})             # post-save GC
    assert not orphan2.exists()
    assert cm.all_steps() == [10]


def test_manifest_records_leaf_metadata(tmp_path):
    cm = CheckpointManager(tmp_path)
    cm.save(10, _tree(), {"step": 10})
    man = json.loads((tmp_path / "step_0000000010" / "manifest.json")
                     .read_text())
    assert man["format"] == 2 and man["num_leaves"] == 2
    assert [m["shape"] for m in man["leaves"]] == [[10], [3, 3]]
    assert [m["dtype"] for m in man["leaves"]] == ["float64", "float64"]
    arr = np.load(tmp_path / "step_0000000010" / "arr_0.npy")
    assert man["leaves"][0]["crc32"] == \
        zlib.crc32(np.ascontiguousarray(arr).tobytes())


def test_legacy_manifest_still_restores(tmp_path):
    """Format-1 manifests (no leaf metadata) restore without verification."""
    cm = CheckpointManager(tmp_path)
    cm.save(10, _tree(), {"step": 10})
    mpath = tmp_path / "step_0000000010" / "manifest.json"
    man = json.loads(mpath.read_text())
    del man["leaves"], man["format"]
    mpath.write_text(json.dumps(man))
    restored, extras, step = cm.restore(_tree())
    assert step == 10
    np.testing.assert_array_equal(restored["b"]["c"], np.ones((3, 3)))


def test_async_save_byte_identical(tmp_path):
    sync = CheckpointManager(tmp_path / "sync")
    sync.save(5, _tree(), {"step": 5})
    asy = CheckpointManager(tmp_path / "async")
    asy.save(5, _tree(), {"step": 5}, background=True)
    asy.wait()
    sdir, adir = tmp_path / "sync/step_0000000005", \
        tmp_path / "async/step_0000000005"
    files = sorted(p.name for p in sdir.iterdir())
    assert files == sorted(p.name for p in adir.iterdir())
    for name in files:
        assert (sdir / name).read_bytes() == (adir / name).read_bytes()
    asy.close()


def test_async_save_failure_surfaces_on_wait(tmp_path):
    def hook(step, phase):
        if phase == "manifest":
            raise OSError("disk full (injected)")
    cm = CheckpointManager(tmp_path, fault_hook=hook)
    cm.save(5, _tree(), {}, background=True)
    with pytest.raises(OSError, match="disk full"):
        cm.wait()
    assert cm.all_steps() == [] and not list(tmp_path.glob("*.tmp.*"))
    cm.close()


def test_async_ckpt_trainer_resume(small_graph, small_parts, tmp_path):
    """Resume from an async-written checkpoint == uninterrupted run."""
    t1 = _trainer(small_graph, small_parts, str(tmp_path / "a"),
                  async_ckpt=True)
    t1.run(20)
    t1.save()
    t1.run(5)
    loss_cont = [h["loss"] for h in t1.history if "loss" in h][-5:]
    t1.close()

    t2 = _trainer(small_graph, small_parts, str(tmp_path / "a"))
    assert t2.restore()
    assert t2.step_num == 20
    t2.run(5)
    loss_resume = [h["loss"] for h in t2.history if "loss" in h][-5:]
    np.testing.assert_allclose(loss_cont, loss_resume, rtol=1e-6)


def test_trainer_restores_from_corrupt_latest(small_graph, small_parts,
                                              tmp_path):
    """End-to-end: corrupt latest step on disk -> trainer resumes from the
    newest verifiable step and keeps training."""
    t1 = _trainer(small_graph, small_parts, str(tmp_path))
    t1.run(30)                                     # checkpoints at 10, 20, 30
    latest = Path(tmp_path) / "step_0000000030" / "arr_0.npy"
    latest.write_bytes(latest.read_bytes()[:64])
    t2 = _trainer(small_graph, small_parts, str(tmp_path))
    assert t2.restore()
    assert t2.step_num == 20
    hist = t2.run(10)
    assert np.isfinite([h["loss"] for h in hist if "loss" in h][-1])
