"""Multi-device SPMD tests (subprocess: device count is locked at jax init).

Small placeholder-device meshes validate the same code paths the 512-device
dry-run uses: the flat multi-cluster LMC step under data/model sharding, and
an LM train step with the full production sharding rules.
"""

from _spmd import run_spmd as _run


def test_distributed_lmc_step_matches_single_device():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.graph import make_sbm_dataset, partition_graph, ClusterSampler
        from repro.core import make_train_step, init_history, from_graph, LMC
        from repro.core.distributed import stack_batches, spmd_shardings
        from repro.core.history import HistoricalState
        from repro.launch.mesh import make_mesh
        from repro.models import make_gnn

        g = make_sbm_dataset("ppi-cpu", seed=3)
        data = from_graph(g)
        parts = partition_graph(g, 8, seed=0)
        gnn = make_gnn("gcn", g.feature_dim, 32, g.num_classes, 2)
        params = gnn.init_params(jax.random.key(0))
        s = ClusterSampler(g, 8, 1, parts=parts, seed=1)
        sgs = [s.build_batch(np.array([d])) for d in range(4)]
        flat = stack_batches(sgs)
        step = make_train_step(gnn, LMC, g.num_nodes)
        store = init_history(2, g.num_nodes, 32)

        # single device reference
        l_ref, g_ref, _, _ = jax.jit(step)(params, store, flat, data.x, data.self_w)

        # 4 data shards x 2 model shards
        mesh = make_mesh((4, 2), ("data", "model"))
        bsh, ssh, xsh, swsh, psh = spmd_shardings(mesh)
        store_sh = HistoricalState(h=ssh["h"], v=ssh["v"])
        params_sh = jax.tree.map(lambda _: psh, params)
        with mesh:
            jstep = jax.jit(step, in_shardings=(params_sh, store_sh, bsh, xsh, swsh))
            l_spmd, g_spmd, _, _ = jstep(params, store, flat, data.x, data.self_w)
        assert abs(float(l_ref) - float(l_spmd)) < 1e-4, (l_ref, l_spmd)
        for a, b in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_spmd)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-4)
        print("SPMD-OK")
    """)
    assert "SPMD-OK" in out


def test_multipod_lmc_step_matches_single_device():
    """The stacked LMC batch end-to-end on the 3-axis ("pod","data","model")
    mesh: rows shard over the fused pod×data axis, stores/features over
    (pod×data, model), all via spmd_shardings — numerics must match a single
    device (DESIGN.md §4; ROADMAP multi-pod dry-run cell)."""
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.graph import make_sbm_dataset, partition_graph, ClusterSampler
        from repro.core import make_train_step, init_history, from_graph, LMC
        from repro.core.distributed import stack_batches, spmd_shardings
        from repro.core.history import HistoricalState
        from repro.launch.mesh import make_mesh
        from repro.models import make_gnn

        g = make_sbm_dataset("ppi-cpu", seed=3)
        data = from_graph(g)
        parts = partition_graph(g, 8, seed=0)
        gnn = make_gnn("gcn", g.feature_dim, 32, g.num_classes, 2)
        params = gnn.init_params(jax.random.key(0))
        s = ClusterSampler(g, 8, 1, parts=parts, seed=1)
        # pod x data = 4 row-parallel ways -> stack 4 per-device clusters
        sgs = [s.build_batch(np.array([d])) for d in range(4)]
        flat = stack_batches(sgs)
        step = make_train_step(gnn, LMC, g.num_nodes)
        store = init_history(2, g.num_nodes, 32)

        l_ref, g_ref, st_ref, _ = jax.jit(step)(params, store, flat,
                                                data.x, data.self_w)

        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        bsh, ssh, xsh, swsh, psh = spmd_shardings(mesh)
        store_sh = HistoricalState(h=ssh["h"], v=ssh["v"])
        params_sh = jax.tree.map(lambda _: psh, params)
        with mesh:
            jstep = jax.jit(step, in_shardings=(params_sh, store_sh, bsh,
                                                xsh, swsh))
            l_3ax, g_3ax, st_3ax, _ = jstep(params, store, flat,
                                            data.x, data.self_w)
        assert abs(float(l_ref) - float(l_3ax)) < 1e-4, (l_ref, l_3ax)
        for a, b in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_3ax)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-3, atol=2e-4)
        # store updates (the halo-exchange collectives) must agree too
        np.testing.assert_allclose(np.asarray(st_ref.h), np.asarray(st_3ax.h),
                                   rtol=2e-3, atol=2e-4)
        print("MULTIPOD-OK")
    """)
    assert "MULTIPOD-OK" in out


def test_lm_train_step_spmd_small_mesh():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs import reduced_config, SHAPES
        from repro.configs.base import ShapeConfig
        from repro.launch.mesh import make_mesh
        from repro.launch.steps import build_cell
        import dataclasses

        cfg = dataclasses.replace(reduced_config("llama3.2-1b"), microbatches=2)
        mesh = make_mesh((4, 2), ("data", "model"))
        shape = ShapeConfig("tiny_train", "train", 64, 8)
        lm, step, args, shs = build_cell(cfg, shape, mesh)
        params = lm.init_params(jax.random.key(0))
        from repro.optim import make_optimizer
        opt = make_optimizer(cfg.optimizer)
        from repro.models.spec import PSpec
        opt_state = opt.init(params, lm.params_spec())
        batch = {"tokens": jnp.arange(8*64, dtype=jnp.int32).reshape(8, 64) % cfg.vocab,
                 "loss_mask": jnp.ones((8, 64), jnp.float32)}
        with mesh:
            p2, s2, m = jax.jit(step, in_shardings=shs)(params, opt_state, batch)
        assert np.isfinite(float(m["loss"])), m
        # params actually changed
        delta = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
                    for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(params)))
        assert delta > 0
        print("LM-SPMD-OK", float(m["loss"]))
    """)
    assert "LM-SPMD-OK" in out


def test_decode_step_spmd_cache_sharding():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs import reduced_config
        from repro.configs.base import ShapeConfig
        from repro.launch.mesh import make_mesh
        from repro.launch.steps import build_cell

        cfg = reduced_config("qwen2.5-32b")
        mesh = make_mesh((2, 4), ("data", "model"))
        shape = ShapeConfig("tiny_decode", "decode", 64, 4)
        lm, step, args, shs = build_cell(cfg, shape, mesh)
        params = lm.init_params(jax.random.key(0))
        caches = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), lm.abstract_cache(4, 64))
        tok = jnp.ones((4, 1), jnp.int32)
        with mesh:
            logits, caches2 = jax.jit(step, in_shardings=shs)(params, caches, tok, jnp.int32(3))
        assert np.isfinite(np.float32(logits)).all()
        print("DECODE-SPMD-OK")
    """)
    assert "DECODE-SPMD-OK" in out
