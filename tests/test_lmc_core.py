"""The paper's core claims, as tests.

  * full-batch exactness: LMC backward message passing == autodiff (Eqs 5-13)
  * Thm 1: backward-SGD estimates are unbiased over uniform cluster sampling
  * Fig 3: gradient bias ordering LMC < GAS < Cluster-GCN
  * the method space (C_f / C_b ablations) runs and stays finite
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (LMC, METHODS, backward_sgd_grads,
                        exact_layer_values, from_graph, full_grads,
                        init_history, make_train_step, to_device_batch)
from repro.graph import ClusterSampler
from repro.models import make_gnn


def _rel(ga, gb):
    f1 = jax.tree.leaves(ga)
    f2 = jax.tree.leaves(gb)
    num = sum(float(jnp.sum((a - b) ** 2)) for a, b in zip(f1, f2))
    den = sum(float(jnp.sum(jnp.asarray(b) ** 2)) for b in f2)
    return (num / max(den, 1e-12)) ** 0.5


@pytest.mark.parametrize("arch", ["gcn", "gcnii", "sage", "gin"])
def test_full_batch_reduces_to_autodiff(arch, small_graph):
    """Batch == whole graph => LMC grads must equal jax.grad exactly."""
    g = small_graph
    data = from_graph(g)
    gnn = make_gnn(arch, g.feature_dim, 32, g.num_classes, 3)
    params = gnn.init_params(jax.random.key(0))
    s = ClusterSampler(g, 1, 1, parts=np.zeros(g.num_nodes, np.int32))
    sg = s.sample()
    assert sg.n_halo_real == 0
    step = jax.jit(make_train_step(gnn, LMC, g.num_nodes))
    store = init_history(gnn.num_layers, g.num_nodes, 32)
    loss, grads, _, _ = step(params, store, to_device_batch(sg), data.x,
                             data.self_w)
    loss_ref, grads_ref = full_grads(gnn, params, data)
    assert abs(float(loss) - float(loss_ref)) < 1e-5
    for a, b in zip(jax.tree.leaves(grads), jax.tree.leaves(grads_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-4, atol=1e-6)


def test_thm1_backward_sgd_unbiased(small_graph, small_parts):
    """Mean of per-cluster backward-SGD estimates == full gradient (Thm 1)."""
    g = small_graph
    data = from_graph(g)
    gnn = make_gnn("gcn", g.feature_dim, 32, g.num_classes, 2)
    params = gnn.init_params(jax.random.key(0))
    hs, vs = exact_layer_values(gnn, params, data)
    _, gref = full_grads(gnn, params, data)
    acc = None
    for p in range(16):
        nodes = jnp.asarray(np.where(small_parts == p)[0])
        gp = backward_sgd_grads(gnn, params, data, hs, vs, nodes, scale=16.0)
        gp = jax.tree.map(lambda x: x / 16.0, gp)
        acc = gp if acc is None else jax.tree.map(jnp.add, acc, gp)
    assert _rel(acc, gref["layers"]) < 1e-4


def test_gradient_bias_ordering(small_graph, small_parts):
    """Fig 3: bias(LMC) < bias(GAS) < bias(Cluster) vs exact backward-SGD."""
    g = small_graph
    data = from_graph(g)
    gnn = make_gnn("gcn", g.feature_dim, 32, g.num_classes, 3)
    params = gnn.init_params(jax.random.key(0))
    hs, vs = exact_layer_values(gnn, params, data)
    biases = {}
    for name in ("lmc", "gas", "cluster"):
        m = METHODS[name]
        s = ClusterSampler(g, 16, 2, parts=small_parts, seed=1,
                           include_halo=m.include_halo,
                           edge_weight_mode=m.edge_weight_mode,
                           stochastic=False)
        step = jax.jit(make_train_step(gnn, m, g.num_nodes))
        store = init_history(gnn.num_layers, g.num_nodes, 32)
        for _ in range(3):
            for sg in s.epoch():
                _, _, store, _ = step(params, store, to_device_batch(sg),
                                      data.x, data.self_w)
        errs = []
        for sg in s.epoch():
            _, gm, store, _ = step(params, store, to_device_batch(sg),
                                   data.x, data.self_w)
            nodes = jnp.asarray(sg.batch_gids[sg.batch_mask > 0])
            gsgd = backward_sgd_grads(gnn, params, data, hs, vs, nodes,
                                      scale=8.0)
            errs.append(_rel(gm["layers"], gsgd))
        biases[name] = float(np.mean(errs))
    assert biases["lmc"] < biases["gas"] < biases["cluster"], biases


@pytest.mark.parametrize("name", list(METHODS))
def test_all_methods_finite(name, small_graph, small_parts):
    g = small_graph
    data = from_graph(g)
    m = METHODS[name]
    gnn = make_gnn("gcn", g.feature_dim, 16, g.num_classes, 2)
    params = gnn.init_params(jax.random.key(1))
    s = ClusterSampler(g, 16, 1, parts=small_parts, seed=0,
                       include_halo=m.include_halo,
                       edge_weight_mode=m.edge_weight_mode)
    step = jax.jit(make_train_step(gnn, m, g.num_nodes))
    store = init_history(2, g.num_nodes, 16)
    loss, grads, store, metrics = step(params, store,
                                       to_device_batch(s.sample()),
                                       data.x, data.self_w)
    assert np.isfinite(float(loss))
    assert all(np.isfinite(np.asarray(x)).all() for x in jax.tree.leaves(grads))


def test_store_updates_only_batch_rows(small_graph, small_parts):
    g = small_graph
    data = from_graph(g)
    gnn = make_gnn("gcn", g.feature_dim, 16, g.num_classes, 2)
    params = gnn.init_params(jax.random.key(1))
    s = ClusterSampler(g, 16, 1, parts=small_parts, seed=0)
    step = jax.jit(make_train_step(gnn, LMC, g.num_nodes))
    store = init_history(2, g.num_nodes, 16)
    sg = s.sample()
    _, _, store2, _ = step(params, store, to_device_batch(sg), data.x,
                           data.self_w)
    changed = np.where(np.any(np.asarray(store2.h[0]) != 0, axis=-1))[0]
    batch = set(sg.batch_gids[sg.batch_mask > 0].tolist())
    assert set(changed.tolist()) <= batch
