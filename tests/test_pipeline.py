"""Tests for repro.data.prefetch.SubgraphPipeline — stream determinism
(prefetch == sync under a fixed seed), minibatch recycling, epoch coverage,
resume, worker-exception propagation and clean shutdown — plus the trainer
integration (GNNTrainer prefetch path vs the schedule-indexed sync path,
and deterministic checkpoint resume through the pipeline)."""
import threading
import time

import jax
import numpy as np
import pytest

from repro.data.prefetch import SubgraphPipeline
from repro.graph import ClusterSampler


def _leaves_equal(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb))


def _pipeline_threads():
    return [t for t in threading.enumerate()
            if t.name.startswith("subgraph-pipeline") and t.is_alive()]


def _wait_until(pred, timeout=5.0):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return True
        time.sleep(0.01)
    return pred()


def _sampler(graph, parts, seed=1, c=2):
    return ClusterSampler(graph, 16, c, parts=parts, seed=seed)


class _RecordingSampler:
    """Duck-typed sampler wrapper recording the schedule slots built."""

    def __init__(self, inner):
        self._inner = inner
        self.calls: list = []   # (slot, cluster-id tuple)
        self._lock = threading.Lock()

    def clusters_at(self, slot, *, mode="uniform"):
        cids = self._inner.clusters_at(slot, mode=mode)
        with self._lock:
            self.calls.append((int(slot), tuple(int(c) for c in cids)))
        return cids

    def build_batch(self, cids):
        return self._inner.build_batch(cids)


class _FailingSampler(_RecordingSampler):
    """Raises from the worker when building a chosen slot."""

    def __init__(self, inner, fail_slot):
        super().__init__(inner)
        self.fail_slot = fail_slot

    def clusters_at(self, slot, *, mode="uniform"):
        if int(slot) == self.fail_slot:
            raise RuntimeError(f"bad slot {slot}")
        return super().clusters_at(slot, mode=mode)


# ------------------------------------------------------------ construction
@pytest.mark.parametrize("kw", [dict(depth=-1), dict(workers=0),
                                dict(recycle=0), dict(start_step=-1)])
def test_invalid_config_rejected(small_graph, small_parts, kw):
    with pytest.raises(ValueError):
        SubgraphPipeline(_sampler(small_graph, small_parts), **kw)


# ------------------------------------------------------------- determinism
def test_prefetch_equals_sync_stream(small_graph, small_parts):
    """depth=2/workers=2 must yield the exact same batches as depth=0:
    the stream is a pure function of the slot index, not of thread timing."""
    n = 6
    with SubgraphPipeline(_sampler(small_graph, small_parts), depth=0,
                          num_steps=n) as sync:
        ref = list(sync)
    with SubgraphPipeline(_sampler(small_graph, small_parts), depth=2,
                          workers=2, num_steps=n) as pre:
        got = list(pre)
    assert len(ref) == len(got) == n
    for r, g in zip(ref, got):
        assert _leaves_equal(r, g)


def test_resume_replays_uninterrupted_tail(small_graph, small_parts):
    """start_step=k (even mid-recycle-window) must reproduce the tail of a
    run started at 0 — the checkpoint-recovery contract."""
    full = list(SubgraphPipeline(_sampler(small_graph, small_parts),
                                 depth=0, recycle=2, num_steps=10))
    with SubgraphPipeline(_sampler(small_graph, small_parts), depth=2,
                          recycle=2, start_step=5, num_steps=5) as tail:
        resumed = list(tail)
    assert len(resumed) == 5
    for r, g in zip(full[5:], resumed):
        assert _leaves_equal(r, g)


# --------------------------------------------------------------- recycling
def test_recycle_reuses_each_subgraph_rho_times(small_graph, small_parts):
    rho, slots = 3, 4
    with SubgraphPipeline(_sampler(small_graph, small_parts), depth=2,
                          recycle=rho, num_steps=rho * slots) as pipe:
        got = list(pipe)
    assert len(got) == rho * slots
    for i in range(0, len(got), rho):
        window = got[i:i + rho]
        assert all(b is window[0] for b in window)   # same object, ρ steps
    distinct = got[::rho]
    for a, b in zip(distinct, distinct[1:]):
        assert a is not b


def test_epoch_coverage_under_recycling(small_graph, small_parts):
    """mode="epoch" with recycling: every partition is built exactly once
    per B/c distinct slots, and only B/c host builds happen for ρ·B/c steps."""
    rho, c, b = 3, 2, 16
    slots_per_epoch = b // c
    rec = _RecordingSampler(_sampler(small_graph, small_parts, c=c))
    with SubgraphPipeline(rec, depth=2, workers=2, recycle=rho, mode="epoch",
                          num_steps=rho * slots_per_epoch) as pipe:
        n = sum(1 for _ in pipe)
    assert n == rho * slots_per_epoch
    assert len(rec.calls) == slots_per_epoch    # 1/ρ of the steps
    built = [cid for _, cids in rec.calls for cid in cids]
    assert sorted(built) == list(range(b))      # each cluster exactly once


# ------------------------------------------------------- failure & shutdown
def test_worker_exception_surfaces_in_slot_order(small_graph, small_parts):
    fail = _FailingSampler(_sampler(small_graph, small_parts), fail_slot=2)
    with SubgraphPipeline(fail, depth=2, workers=2, num_steps=6) as pipe:
        assert next(pipe) is not None
        assert next(pipe) is not None
        with pytest.raises(RuntimeError, match="bad slot 2"):
            next(pipe)


def test_consumer_raise_mid_epoch_shuts_down_cleanly(small_graph, small_parts):
    """A consumer raising mid-epoch must still stop every worker thread
    (the context manager closes the pipeline without swallowing the error)."""
    with pytest.raises(ValueError, match="consumer bug"):
        with SubgraphPipeline(_sampler(small_graph, small_parts), depth=2,
                              workers=2) as pipe:
            next(pipe)
            next(pipe)
            raise ValueError("consumer bug")
    assert _wait_until(lambda: not _pipeline_threads()), (
        f"pipeline threads survived close(): {_pipeline_threads()}")
    with pytest.raises(StopIteration):
        next(pipe)


def test_close_is_idempotent(small_graph, small_parts):
    pipe = SubgraphPipeline(_sampler(small_graph, small_parts), depth=1)
    next(pipe)
    pipe.close()
    pipe.close()
    assert _wait_until(lambda: not _pipeline_threads())


# ------------------------------------------------------ trainer integration
def _make_trainer(graph, parts, **kw):
    from repro.core import LMC
    from repro.models import make_gnn
    from repro.optim import sgd
    from repro.train import GNNTrainer
    gnn = make_gnn("gcn", graph.feature_dim, 32, graph.num_classes, 2)
    s = _sampler(graph, parts)
    return GNNTrainer(gnn, LMC, graph, s, sgd(lr=0.2), seed=0, **kw)


def test_trainer_prefetch_matches_sync(small_graph, small_parts):
    """GNNTrainer(prefetch=2) must produce the identical loss trajectory to
    prefetch=0 (same schedule, synchronous builds)."""
    ta = _make_trainer(small_graph, small_parts, prefetch=0)
    ta.run(6)
    tb = _make_trainer(small_graph, small_parts, prefetch=2)
    tb.run(6)
    tb.close()
    la = [h["loss"] for h in ta.history]
    lb = [h["loss"] for h in tb.history]
    assert la == lb


def test_trainer_resume_through_pipeline(tmp_path, small_graph, small_parts):
    """Checkpoint restore + pipeline rebuild replays the uninterrupted run."""
    ref = _make_trainer(small_graph, small_parts, prefetch=2, recycle=2)
    ref.run(8)
    ref.close()

    ta = _make_trainer(small_graph, small_parts, prefetch=2, recycle=2,
                       ckpt_dir=str(tmp_path), ckpt_every=4)
    ta.run(4)
    ta.save()
    ta.close()
    tb = _make_trainer(small_graph, small_parts, prefetch=2, recycle=2,
                       ckpt_dir=str(tmp_path), ckpt_every=4)
    assert tb.restore()
    assert tb.step_num == 4
    tb.run(4)
    tb.close()
    assert _leaves_equal(ref.params, tb.params)


def test_trainer_close_stops_workers(small_graph, small_parts):
    tr = _make_trainer(small_graph, small_parts, prefetch=2)
    tr.run(2)
    tr.close()
    assert _wait_until(lambda: not _pipeline_threads())
