import numpy as np
import pytest
from hypothesis import settings, HealthCheck

# fast, CPU-friendly hypothesis profile (single-core container)
settings.register_profile(
    "repro", max_examples=12, deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large])
settings.load_profile("repro")


@pytest.fixture(scope="session")
def small_graph():
    from repro.graph import make_sbm_dataset
    return make_sbm_dataset("ppi-cpu", seed=3)


@pytest.fixture(scope="session")
def small_parts(small_graph):
    from repro.graph import partition_graph
    return partition_graph(small_graph, 16, seed=0)
