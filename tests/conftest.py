import os
import sys

import numpy as np
import pytest

# make `from _prop import ...` resolve from test modules under any pytest
# import mode (and degrade gracefully when hypothesis is absent — see _prop)
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from _prop import HAVE_HYPOTHESIS, HealthCheck, settings

# fast, CPU-friendly hypothesis profile (single-core container); a no-op
# under the _prop fallback
settings.register_profile(
    "repro", max_examples=12, deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large])
settings.load_profile("repro")


@pytest.fixture(scope="session")
def small_graph():
    from repro.graph import make_sbm_dataset
    return make_sbm_dataset("ppi-cpu", seed=3)


@pytest.fixture(scope="session")
def small_parts(small_graph):
    from repro.graph import partition_graph
    return partition_graph(small_graph, 16, seed=0)
