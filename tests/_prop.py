"""Property-test shim: real `hypothesis` when installed, else a minimal
single-example fallback so `@given` tests still run one deterministic case
(this container has no network, so the wheel may be absent).

Test modules import `given`, `settings`, `strategies` from here instead of
from `hypothesis` directly; the fallback draws each strategy's midpoint-ish
representative value once, keeping collection green and the oracle exercised.
"""
from __future__ import annotations

try:
    from hypothesis import HealthCheck, given, settings, strategies
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False
    import functools
    import inspect

    class HealthCheck:  # names conftest's profile refers to
        too_slow = "too_slow"
        data_too_large = "data_too_large"

    class _Strategy:
        def __init__(self, value):
            self._value = value

        def example(self):
            return self._value

    class _Strategies:
        @staticmethod
        def integers(min_value=0, max_value=0):
            return _Strategy(min_value + (max_value - min_value) // 2)

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy(0.5 * (min_value + max_value))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(elements[len(elements) // 2])

        @staticmethod
        def booleans():
            return _Strategy(True)

        @staticmethod
        def just(value):
            return _Strategy(value)

    strategies = _Strategies()

    def given(*args, **kwargs):
        assert not args, "fallback @given supports keyword strategies only"

        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*a, **kw):
                kw.update({k: s.example() for k, s in kwargs.items()})
                return fn(*a, **kw)
            # hide the strategy-filled params from pytest's fixture resolution
            params = [p for name, p in inspect.signature(fn).parameters.items()
                      if name not in kwargs]
            wrapper.__signature__ = inspect.Signature(params)
            return wrapper
        return deco

    class settings:
        """Accepts (and ignores) every hypothesis settings knob."""

        def __init__(self, *a, **kw):
            pass

        def __call__(self, fn):
            return fn

        @staticmethod
        def register_profile(*a, **kw):
            pass

        @staticmethod
        def load_profile(*a, **kw):
            pass


st = strategies
