"""Graph substrate: structures, partitioner, sampler invariants."""
import numpy as np
from _prop import given, strategies as st

from repro.graph import ClusterSampler, edge_cut_fraction, make_sbm_dataset
from repro.graph.partition import partition_balance
from repro.graph.structure import beta_score


def test_graph_symmetry(small_graph):
    g = small_graph
    # undirected: every edge appears in both directions
    src = np.repeat(np.arange(g.num_nodes), np.diff(g.indptr))
    fwd = set(zip(src.tolist(), g.indices.tolist()))
    assert all((b, a) in fwd for a, b in list(fwd)[:2000])
    assert not any(a == b for a, b in list(fwd)[:2000])


def test_partition_balance_and_cut(small_graph, small_parts):
    assert partition_balance(small_parts, 16) <= 1.06
    # must beat a random partition's cut by a wide margin
    rng = np.random.default_rng(0)
    rand = rng.integers(0, 16, small_graph.num_nodes).astype(np.int32)
    assert edge_cut_fraction(small_graph, small_parts) \
        < 0.8 * edge_cut_fraction(small_graph, rand)


@given(c=st.integers(1, 4), seed=st.integers(0, 5))
def test_sampler_padding_invariants(c, seed):
    g = make_sbm_dataset("ppi-cpu", seed=3)
    s = ClusterSampler(g, 16, c, seed=seed)
    sg = s.sample()
    ne = sg.n_ext
    assert sg.edge_src.max() < ne and sg.edge_dst.max() < ne
    assert sg.batch_mask.sum() == sg.n_batch_real
    assert sg.halo_mask.sum() == sg.n_halo_real
    # padded edges carry zero weight
    assert np.all(sg.edge_w[sg.n_edges_real:] == 0)
    # batch and halo are disjoint
    b = set(sg.batch_gids[sg.batch_mask > 0].tolist())
    h = set(sg.halo_gids[sg.halo_mask > 0].tolist())
    assert not (b & h)


def test_epoch_covers_every_cluster(small_graph, small_parts):
    s = ClusterSampler(small_graph, 16, 2, parts=small_parts, seed=0)
    seen = set()
    for sg in s.epoch():
        seen.update(sg.batch_gids[sg.batch_mask > 0].tolist())
    assert len(seen) == small_graph.num_nodes


def test_subgraph_edges_match_graph(small_graph, small_parts):
    s = ClusterSampler(small_graph, 16, 1, parts=small_parts, seed=0)
    sg = s.sample()
    g = small_graph
    gids = np.concatenate([sg.batch_gids, sg.halo_gids])
    # every real edge exists in the original graph
    for e in range(0, sg.n_edges_real, 97):
        u, v = gids[sg.edge_src[e]], gids[sg.edge_dst[e]]
        assert u in g.neighbors(v)


@given(score=st.sampled_from(["x2", "2x-x2", "x", "1", "sin"]),
       alpha=st.floats(0.0, 1.0))
def test_beta_scores_in_unit_interval(score, alpha):
    ld = np.array([0, 1, 5, 10])
    gd = np.array([1, 2, 5, 100])
    b = beta_score(ld, gd, score, alpha)
    assert np.all(b >= 0) and np.all(b <= 1)
