"""Graph substrate: structures, partitioner, sampler invariants."""
import numpy as np
from _prop import given, strategies as st

from repro.graph import ClusterSampler, edge_cut_fraction, make_sbm_dataset
from repro.graph.partition import partition_balance
from repro.graph.structure import beta_score


def test_graph_symmetry(small_graph):
    g = small_graph
    # undirected: every edge appears in both directions
    src = np.repeat(np.arange(g.num_nodes), np.diff(g.indptr))
    fwd = set(zip(src.tolist(), g.indices.tolist()))
    assert all((b, a) in fwd for a, b in list(fwd)[:2000])
    assert not any(a == b for a, b in list(fwd)[:2000])


def test_partition_balance_and_cut(small_graph, small_parts):
    assert partition_balance(small_parts, 16) <= 1.06
    # must beat a random partition's cut by a wide margin
    rng = np.random.default_rng(0)
    rand = rng.integers(0, 16, small_graph.num_nodes).astype(np.int32)
    assert edge_cut_fraction(small_graph, small_parts) \
        < 0.8 * edge_cut_fraction(small_graph, rand)


@given(c=st.integers(1, 4), seed=st.integers(0, 5))
def test_sampler_padding_invariants(c, seed):
    g = make_sbm_dataset("ppi-cpu", seed=3)
    s = ClusterSampler(g, 16, c, seed=seed)
    sg = s.sample()
    ne = sg.n_ext
    assert sg.edge_src.max() < ne and sg.edge_dst.max() < ne
    assert sg.batch_mask.sum() == sg.n_batch_real
    assert sg.halo_mask.sum() == sg.n_halo_real
    # padded edges carry zero weight
    assert np.all(sg.edge_w[sg.n_edges_real:] == 0)
    # batch and halo are disjoint
    b = set(sg.batch_gids[sg.batch_mask > 0].tolist())
    h = set(sg.halo_gids[sg.halo_mask > 0].tolist())
    assert not (b & h)


def test_epoch_covers_every_cluster(small_graph, small_parts):
    s = ClusterSampler(small_graph, 16, 2, parts=small_parts, seed=0)
    seen = set()
    for sg in s.epoch():
        seen.update(sg.batch_gids[sg.batch_mask > 0].tolist())
    assert len(seen) == small_graph.num_nodes


def test_subgraph_edges_match_graph(small_graph, small_parts):
    s = ClusterSampler(small_graph, 16, 1, parts=small_parts, seed=0)
    sg = s.sample()
    g = small_graph
    gids = np.concatenate([sg.batch_gids, sg.halo_gids])
    # every real edge exists in the original graph
    for e in range(0, sg.n_edges_real, 97):
        u, v = gids[sg.edge_src[e]], gids[sg.edge_dst[e]]
        assert u in g.neighbors(v)


@given(seed=st.integers(0, 20), qseed=st.integers(0, 20))
def test_epoch_schedule_covers_under_shuffled_queries(seed, qseed, small_graph,
                                                     small_parts):
    """``clusters_at(i, mode="epoch")`` is pure in (seed, i): querying the
    slots of any epoch in arbitrary (concurrent-style) shuffled order still
    yields every cluster exactly once per epoch, and repeated queries of the
    same slot agree."""
    s = ClusterSampler(small_graph, 16, 3, parts=small_parts, seed=seed)
    bpe = s.batches_per_epoch            # 16 // 3 = 5 slots, 15 clusters/epoch
    q = np.random.default_rng(qseed)
    for epoch in range(3):
        slots = epoch * bpe + q.permutation(bpe)     # shuffled query order
        got = np.concatenate([s.clusters_at(int(i), mode="epoch")
                              for i in slots])
        assert len(got) == bpe * s.c
        assert len(np.unique(got)) == bpe * s.c      # no cluster twice
        # replay: the same slot queried again returns the same ids
        i = int(slots[0])
        np.testing.assert_array_equal(s.clusters_at(i, mode="epoch"),
                                      s.clusters_at(i, mode="epoch"))


def test_sampler_state_roundtrip_mid_epoch(small_graph, small_parts):
    """state_dict/load_state_dict restore the stateful RNG mid-epoch: a fresh
    sampler loaded with the saved state replays the identical remainder of
    the stream (both sample() draws and stochastic epoch() grouping)."""
    a = ClusterSampler(small_graph, 16, 2, parts=small_parts, seed=7,
                       stochastic=True)
    for _ in range(3):                   # advance into the stream
        a.sample()
    it = a.epoch()
    next(it)                             # consume part of an epoch
    saved = a.state_dict()

    b = ClusterSampler(small_graph, 16, 2, parts=small_parts, seed=0,
                       stochastic=True)  # different seed: state must win
    b.load_state_dict(saved)
    for _ in range(4):
        sa, sb = a.sample(), b.sample()
        np.testing.assert_array_equal(sa.batch_gids, sb.batch_gids)
        np.testing.assert_array_equal(sa.halo_gids, sb.halo_gids)
        np.testing.assert_array_equal(sa.edge_src, sb.edge_src)
    ea = [sg.batch_gids[sg.batch_mask > 0] for sg in a.epoch()]
    eb = [sg.batch_gids[sg.batch_mask > 0] for sg in b.epoch()]
    for xa, xb in zip(ea, eb):
        np.testing.assert_array_equal(xa, xb)


@given(score=st.sampled_from(["x2", "2x-x2", "x", "1", "sin"]),
       alpha=st.floats(0.0, 1.0))
def test_beta_scores_in_unit_interval(score, alpha):
    ld = np.array([0, 1, 5, 10])
    gd = np.array([1, 2, 5, 100])
    b = beta_score(ld, gd, score, alpha)
    assert np.all(b >= 0) and np.all(b <= 1)
