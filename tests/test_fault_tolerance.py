"""Checkpoint/restart, preemption recovery, elastic rescale, data resume."""
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core import LMC
from repro.data import TokenStream
from repro.graph import ClusterSampler
from repro.models import make_gnn
from repro.optim import sgd
from repro.train import FailureInjector, GNNTrainer, rescale_lmc_state


def _trainer(g, parts, tmp, **kw):
    gnn = make_gnn("gcn", g.feature_dim, 32, g.num_classes, 2)
    s = ClusterSampler(g, 16, 2, parts=parts, seed=1)
    return GNNTrainer(gnn, LMC, g, s, sgd(lr=0.3), ckpt_dir=tmp,
                      ckpt_every=10, **kw)


def test_checkpoint_roundtrip(tmp_path):
    cm = CheckpointManager(tmp_path, keep=2)
    tree = {"a": np.arange(10.0), "b": {"c": np.ones((3, 3))}}
    for step in (10, 20, 30):
        cm.save(step, tree, {"step": step})
    assert cm.all_steps() == [20, 30]  # retention
    restored, extras, step = cm.restore(tree)
    assert step == 30 and extras["step"] == 30
    np.testing.assert_array_equal(restored["a"], tree["a"])


def test_preemption_recovery(small_graph, small_parts, tmp_path):
    inj = FailureInjector(fail_at_steps=(25,))
    tr = _trainer(small_graph, small_parts, str(tmp_path),
                  failure_injector=inj)
    hist = tr.run(50)
    events = [h for h in hist if h.get("event") == "preemption"]
    assert len(events) == 1 and events[0]["restored"]
    assert tr.step_num == 50
    losses = [h["loss"] for h in hist if "loss" in h]
    assert losses[-1] < losses[0]


def test_resume_is_deterministic(small_graph, small_parts, tmp_path):
    """Restore + continue == uninterrupted run (same sampler state)."""
    t1 = _trainer(small_graph, small_parts, str(tmp_path / "a"))
    t1.run(20)
    t1.save()
    t1.run(5)
    loss_cont = [h["loss"] for h in t1.history if "loss" in h][-5:]

    t2 = _trainer(small_graph, small_parts, str(tmp_path / "a"))
    assert t2.restore()
    assert t2.step_num == 20
    t2.run(5)
    loss_resume = [h["loss"] for h in t2.history if "loss" in h][-5:]
    np.testing.assert_allclose(loss_cont, loss_resume, rtol=1e-6)


def test_elastic_rescale(small_graph, small_parts, tmp_path):
    tr = _trainer(small_graph, small_parts, str(tmp_path))
    tr.run(10)
    # scale 16 -> 8 clusters; stores survive (per-node state)
    sampler2, store2 = rescale_lmc_state(
        small_graph, tr.store, old_num_parts=16, new_num_parts=8, seed=1)
    assert sampler2.num_parts == 8
    np.testing.assert_array_equal(np.asarray(store2.h), np.asarray(tr.store.h))
    tr.sampler = sampler2
    tr.store = store2
    hist = tr.run(5)
    assert np.isfinite([h["loss"] for h in hist if "loss" in h][-1])


def test_token_stream_resume():
    a = TokenStream(1000, 4, 32, seed=7)
    batches = [next(a) for _ in range(5)]
    b = TokenStream(1000, 4, 32, seed=7)
    b.load_state_dict({"step": 3})
    np.testing.assert_array_equal(next(b)["tokens"], batches[3]["tokens"])
    np.testing.assert_array_equal(next(b)["tokens"], batches[4]["tokens"])


def test_straggler_skip_store(small_graph, small_parts, tmp_path):
    tr = _trainer(small_graph, small_parts, str(tmp_path),
                  straggler_deadline=0.0)  # every step after warmup is late
    hist = tr.run(15)
    assert any(h.get("straggler") for h in hist if "loss" in h)
    # training still progresses (store updates skipped, not the params)
    losses = [h["loss"] for h in hist if "loss" in h]
    assert losses[-1] < losses[0]
