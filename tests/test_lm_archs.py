"""Per-arch smoke tests: reduced config of the same family, one forward /
train step on CPU asserting output shapes + no NaNs, plus decode-vs-prefill
consistency (the recurrent/absorbed-cache paths)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, SHAPES, applicable_shapes, get_config, \
    reduced_config
from repro.models.lm import LM

# bf16 + capacity-dropping MoE give the loosest tolerances
TOL = {"moe": 0.12, "hybrid": 0.05, "default": 0.02}


def _mem(cfg, b):
    if cfg.family in ("vlm", "encdec"):
        t = cfg.frontend_tokens or 16
        return (jax.random.normal(jax.random.key(8), (b, t, cfg.d_model))
                * 0.05).astype(jnp.bfloat16)
    return None


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_arch_smoke_train(name):
    cfg = reduced_config(name)
    lm = LM(cfg)
    params = lm.init_params(jax.random.key(0))
    b, s = 2, 64
    batch = {"tokens": jnp.arange(b * s).reshape(b, s) % cfg.vocab,
             "loss_mask": jnp.ones((b, s), jnp.float32)}
    mem = _mem(cfg, b)
    if mem is not None:
        batch["memory"] = mem
    loss = jax.jit(lm.train_loss)(params, batch)
    assert np.isfinite(float(loss)), (name, float(loss))
    expected = np.log(cfg.vocab) * (1.3 if cfg.mtp_depth else 1.0)
    assert abs(float(loss) - expected) < 0.25 * expected


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_arch_decode_matches_prefill(name):
    cfg = reduced_config(name)
    lm = LM(cfg)
    params = lm.init_params(jax.random.key(1))
    b, s = 2, 32
    toks = jax.random.randint(jax.random.key(7), (b, s + 1), 0, cfg.vocab)
    mem = _mem(cfg, b)
    ref, _ = jax.jit(lambda p, t: lm.prefill(p, t, 64, mem))(params, toks)
    _, caches = jax.jit(lambda p, t: lm.prefill(p, t, 64, mem))(
        params, toks[:, :s])
    out, _ = jax.jit(lambda p, c, t: lm.decode_step(p, c, t, jnp.int32(s),
                                                    mem))(params, caches,
                                                          toks[:, s:s + 1])
    err = (np.abs(np.float32(ref) - np.float32(out)).max()
           / max(np.abs(np.float32(ref)).max(), 1e-6))
    tol = TOL.get(cfg.family, TOL["default"])
    assert err < tol, (name, err)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_arch_grads_finite(name):
    cfg = reduced_config(name)
    lm = LM(cfg)
    params = lm.init_params(jax.random.key(0))
    b, s = 2, 32
    batch = {"tokens": jax.random.randint(jax.random.key(3), (b, s), 0,
                                          cfg.vocab),
             "loss_mask": jnp.ones((b, s), jnp.float32)}
    mem = _mem(cfg, b)
    if mem is not None:
        batch["memory"] = mem
    g = jax.jit(jax.grad(lm.train_loss))(params, batch)
    assert all(np.isfinite(np.float32(x)).all() for x in jax.tree.leaves(g))


def test_shape_grid_covers_40_cells():
    cells = sum(len(applicable_shapes(get_config(a))) for a in ARCH_NAMES)
    skips = sum(len(SHAPES) - len(applicable_shapes(get_config(a)))
                for a in ARCH_NAMES)
    assert cells + skips == 40
    # long_500k runs exactly for the sub-quadratic archs
    assert sorted(a for a in ARCH_NAMES
                  if "long_500k" in applicable_shapes(get_config(a))) == \
        ["rwkv6-7b", "zamba2-1.2b"]


def test_param_counts_match_public_figures():
    expect = {"llama3.2-1b": 1.24e9, "qwen2.5-32b": 32.8e9,
              "internlm2-20b": 19.9e9, "deepseek-coder-33b": 33.3e9,
              "deepseek-v3-671b": 671e9, "deepseek-v2-lite-16b": 15.7e9,
              "rwkv6-7b": 7.6e9}
    for name, target in expect.items():
        got = get_config(name).param_count()
        assert abs(got - target) / target < 0.2, (name, got, target)
