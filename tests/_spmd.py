"""Shared subprocess harness for multi-device SPMD tests.

Device count is locked at jax init, so anything needing fake devices runs in
a fresh interpreter with XLA_FLAGS set. Used by test_distributed.py and
test_dist_sharding.py.
"""
import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_spmd(code: str, *, devices: int = 8) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout
