"""`repro.dist` — the sharding subsystem (DESIGN.md §4).

Two layers:
  * :mod:`repro.dist.mesh`      — version-compatible mesh construction
    (feature-detects `jax.make_mesh` / `AxisType`, falls back to
    `mesh_utils.create_device_mesh`);
  * :mod:`repro.dist.sharding`  — the single source of truth for how
    activations, LMC historical stores, stacked multi-device Batches and LM
    decode caches map onto mesh axes: constraint helpers (`shard_act`,
    `shard_res`), the activation-sharding mesh registry, and the
    `NamedSharding` factories the launcher / dry-run / trainer consume.

Everything degrades to a no-op off-mesh so single-device smoke tests run the
exact same model code as the 512-device dry-run.
"""
from repro.dist.mesh import make_mesh, make_production_mesh
from repro.dist.sharding import (activation_sharding, current_mesh, data_axes,
                                 dp_axis_size, dp_entry, model_axis_size,
                                 named, replicated, row_sharding, shard_act,
                                 shard_res, store_sharding)

__all__ = [
    "make_mesh", "make_production_mesh",
    "activation_sharding", "current_mesh", "data_axes", "dp_axis_size",
    "dp_entry", "model_axis_size", "named", "replicated", "row_sharding",
    "shard_act", "shard_res", "store_sharding",
]
