"""Activation + state sharding: one place that maps arrays onto mesh axes.

Axis naming convention (DESIGN.md §4): meshes use up to three named axes —
``pod`` (across pods), ``data`` (row/batch parallel) and ``model`` (tensor /
sequence parallel). Model code never names mesh axes directly; it labels array
dims with the *logical* tags ``"dp"`` (rows: the pod+data product axis),
``"model"`` or ``None`` and calls :func:`shard_act`. The labels resolve
against the mesh registered with :func:`activation_sharding` (or the ambient
``with mesh:`` context), so the same model source traces to a no-op on one
device and to `with_sharding_constraint`s on a pod.

Resolution drops any label whose dim is not divisible by the target axes'
size — tiny smoke configs (e.g. 2-row batches on a 4-way data axis) fall back
to replication instead of erroring, mirroring `models.spec.partition_spec`.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

DATA_AXES = ("pod", "data")
MODEL_AXIS = "model"


# ------------------------------------------------------- mesh-context registry
class _MeshStack(threading.local):
    def __init__(self):
        self.stack: list = []


_CTX = _MeshStack()


def current_mesh() -> Optional[Mesh]:
    """The mesh activations shard against, or None off-mesh.

    Priority: innermost :func:`activation_sharding` context, then the legacy
    ambient ``with mesh:`` context manager (so hand-rolled jit calls in tests
    still resolve), else None.
    """
    if _CTX.stack:
        return _CTX.stack[-1]
    try:
        from jax.interpreters import pxla
        env_mesh = pxla.thread_resources.env.physical_mesh
        if env_mesh is not None and not env_mesh.empty:
            return env_mesh
    except Exception:  # moved/removed in newer jax — registry still works
        pass
    return None


@contextlib.contextmanager
def activation_sharding(mesh: Optional[Mesh]):
    """Register `mesh` as the target for `shard_act`/`shard_res`.

    Entered around *tracing* (the launcher wraps the step fn before `jit`), so
    constraints bake into the jaxpr. ``activation_sharding(None)`` explicitly
    disables sharding in the dynamic extent (used by oracle replays).
    """
    _CTX.stack.append(mesh)
    try:
        yield mesh
    finally:
        _CTX.stack.pop()


# ------------------------------------------------------------ axis arithmetic
def _axis_sizes(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def data_axes(mesh: Mesh) -> tuple:
    """The row-parallel axes present in `mesh`, in (pod, data) order."""
    return tuple(a for a in DATA_AXES if a in mesh.axis_names)


def dp_entry(mesh: Mesh):
    """PartitionSpec entry for the fused row axis: tuple, name, or None."""
    axes = data_axes(mesh)
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def dp_axis_size(mesh: Optional[Mesh] = None) -> int:
    """Total row-parallel ways (pod × data); 1 off-mesh."""
    mesh = mesh if mesh is not None else current_mesh()
    if mesh is None:
        return 1
    sizes = _axis_sizes(mesh)
    return int(np.prod([sizes[a] for a in data_axes(mesh)], initial=1))

def model_axis_size(mesh: Optional[Mesh] = None) -> int:
    """Size of the model (tensor/sequence-parallel) axis; 1 off-mesh."""
    mesh = mesh if mesh is not None else current_mesh()
    if mesh is None:
        return 1
    return int(_axis_sizes(mesh).get(MODEL_AXIS, 1))


# --------------------------------------------------------- constraint helpers
def resolve_spec(mesh: Mesh, dims: Sequence[int], labels: Sequence) -> P:
    """Map per-dim labels ("dp" | axis name | None) to a PartitionSpec.

    A label is dropped (-> None) when its axes are absent, already used by an
    earlier dim, trivial (product 1), or do not divide the dim size.
    """
    sizes = _axis_sizes(mesh)
    used: set = set()
    entries = []
    for dim, lbl in zip(dims, labels):
        if lbl is None:
            entries.append(None)
            continue
        axes = data_axes(mesh) if lbl == "dp" else (lbl,)
        axes = tuple(a for a in axes if a in sizes and a not in used)
        total = int(np.prod([sizes[a] for a in axes], initial=1))
        if not axes or total == 1 or dim % total != 0:
            entries.append(None)
            continue
        used.update(axes)
        entries.append(axes if len(axes) > 1 else axes[0])
    return P(*entries)


def shard_act(x: jax.Array, *labels) -> jax.Array:
    """Constrain activation `x` (one label per dim); identity off-mesh."""
    mesh = current_mesh()
    if mesh is None or mesh.size <= 1:
        return x
    if len(labels) != x.ndim:
        raise ValueError(
            f"shard_act: {len(labels)} labels for rank-{x.ndim} array "
            f"(shape {x.shape}, labels {labels})")
    spec = resolve_spec(mesh, x.shape, labels)
    if all(e is None for e in spec):
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def shard_res(x: jax.Array) -> jax.Array:
    """Residual-stream policy for (B, S, d): rows over dp, sequence over
    `model` when S divides it (sequence parallelism between blocks — the MoE
    dispatch and attention then all-gather S exactly once per layer)."""
    if x.ndim == 3:
        return shard_act(x, "dp", MODEL_AXIS, None)
    return shard_act(x, "dp", *(None,) * (x.ndim - 1))


def concat_rows(parts: Sequence[jax.Array], axis: int = 0,
                labels: Optional[Sequence] = None) -> jax.Array:
    """Concatenate array blocks with explicitly pinned result sharding.

    jax 0.4.37's partitioner miscompiles `concatenate` whenever an operand or
    the result is sharded on a multi-axis mesh: the output comes back summed
    over the other mesh axes (observed on the (data, model) grid — every value
    doubled by the 2-way model axis, for any operand size, with or without
    explicit constraints on the operands). `dynamic_update_slice` of the same
    blocks into a zeros buffer partitions correctly for every tested sharding
    combination, so on-mesh the concat is expressed that way, with the result
    pinned to an explicit sharding. The pin is total and applied even when
    every label resolves to replicated — leaving the result unconstrained
    would hand it back to the propagation pass that miscompiles. Off-mesh this
    is exactly `jnp.concatenate`, so mesh-agnostic core code can use it
    unconditionally.

    ``labels`` gives one :func:`shard_act`-style label per result dim (for
    feature-axis concats of sharded activations, e.g. the MLA nope|rope
    head-dim concat). Default: ``"dp"`` on `axis`, replicated elsewhere — the
    [batch | halo] row-block layout of `core/lmc.py`. A (rows,
    model-sharded-features) default output is deliberately traded for
    correctness here.
    """
    parts = list(parts)
    mesh = current_mesh()
    if mesh is None or mesh.size <= 1:
        return jnp.concatenate(parts, axis=axis)

    dtype = jnp.result_type(*parts)  # match jnp.concatenate's promotion
    shape = list(parts[0].shape)
    shape[axis] = sum(int(x.shape[axis]) for x in parts)
    out = jnp.zeros(tuple(shape), dtype)
    offset = 0
    for x in parts:
        start = [0] * out.ndim
        start[axis] = offset
        out = jax.lax.dynamic_update_slice(out, x.astype(dtype), tuple(start))
        offset += int(x.shape[axis])
    if labels is None:
        labels = [None] * out.ndim
        labels[axis % out.ndim] = "dp"
    spec = resolve_spec(mesh, out.shape, labels)
    return jax.lax.with_sharding_constraint(out, NamedSharding(mesh, spec))


# ------------------------------------------------------ NamedSharding factory
def named(mesh: Mesh, *entries) -> NamedSharding:
    return NamedSharding(mesh, P(*entries))


def replicated(mesh: Mesh) -> NamedSharding:
    return named(mesh)


def row_sharding(mesh: Mesh) -> NamedSharding:
    """Per-row 1-D arrays (gids, masks, edge lists): leading dim over dp."""
    return named(mesh, dp_entry(mesh))


def store_sharding(mesh: Mesh, *, model_axis: str | None = MODEL_AXIS,
                   leading_dims: int = 1) -> NamedSharding:
    """LMC historical stores ``(L, n, d)`` (and friends): node axis over dp,
    feature axis over `model_axis` when present (DESIGN.md §3/§4)."""
    feat = model_axis if model_axis in mesh.axis_names else None
    return named(mesh, *(None,) * leading_dims, dp_entry(mesh), feat)
