"""Version-compatible mesh construction.

`jax.make_mesh` + `jax.sharding.AxisType` only exist in newer jax; the pinned
0.4.37 has `make_mesh` but no `AxisType`. Construction feature-detects, in
order: `jax.make_mesh(..., axis_types=...)`, `jax.make_mesh(...)`, and finally
`mesh_utils.create_device_mesh` + `Mesh` — so the same call sites run on every
supported jax without touching device state at import time (meshes are built
by FUNCTIONS: smoke tests must see 1 device while the dry-run sees 512
placeholder devices via XLA_FLAGS).
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
from jax.sharding import Mesh


def make_mesh(shape: Sequence[int], axes: Sequence[str], *,
              devices: Optional[Sequence] = None) -> Mesh:
    """Arbitrary mesh (tests, elastic re-scale, production grids)."""
    shape, axes = tuple(int(s) for s in shape), tuple(axes)
    kwargs = {} if devices is None else {"devices": devices}
    make = getattr(jax, "make_mesh", None)
    if make is not None:
        axis_type = getattr(jax.sharding, "AxisType", None)
        if axis_type is not None:
            try:
                return make(shape, axes,
                            axis_types=(axis_type.Auto,) * len(axes), **kwargs)
            except TypeError:
                pass  # make_mesh predates the axis_types kwarg
        try:
            return make(shape, axes, **kwargs)
        except TypeError:
            pass  # very old make_mesh signature — fall through
    from jax.experimental import mesh_utils
    devs = mesh_utils.create_device_mesh(shape, devices=devices)
    return Mesh(devs, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """The dry-run/production grid: 256 chips per pod, 16-way model axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)
