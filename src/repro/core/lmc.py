"""Local Message Compensation — the paper's Algorithm 1, in JAX.

One unified, jit-compiled train step implements LMC, GAS, Cluster-GCN and the
C_f/C_b ablations (see core/methods.py). The backward pass is *explicit*
message passing (paper Eq. 11–13) built from per-layer ``jax.vjp`` calls — not
autodiff through the stale forward:

  * cotangent ``[V̄_batch ; V̂_halo]``  -> adjoint recursion (Eqs. 11 & 13)
  * cotangent ``[V̄_batch ; 0]``       -> θ-gradients (Eq. 7 sums in-batch rows only)

Both are evaluations of the same linear vjp, so LMC costs exactly one extra
cotangent application per layer versus GAS — matching the paper's complexity
table (Table 5).
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.history import HistoricalState, gather_rows, scatter_rows
from repro.core.methods import MBMethod
from repro.dist.sharding import concat_rows
from repro.graph.structure import PaddedSubgraph
from repro.kernels import ELLGraph, ell_from_coo, lmc_compensate
from repro.models.gnn import GNN, EdgeList, LayerAux

AGG_BACKENDS = ("segment", "ell", "ti")


class Batch(NamedTuple):
    """Device-side view of a PaddedSubgraph (all jnp arrays).

    ``ell`` (optional) carries the batch-local adjacency re-bucketed into the
    Pallas kernel's padded-ELL layout (built host-side by ``to_device_batch``
    with fixed per-bucket capacities, so every batch of a sampler shares one
    jit trace); required by ``make_train_step(..., backend="ell"|"ti")``.

    ``ti_scale`` (optional) carries the per-halo-row message-invariance
    scales α (graph/structure.py builds them next to β); required by
    ``backend="ti"``, whose compensation is α ⊙ fresh instead of a
    historical-store gather (DESIGN.md §11).
    """
    batch_gids: jax.Array
    halo_gids: jax.Array
    batch_mask: jax.Array
    halo_mask: jax.Array
    edge_src: jax.Array
    edge_dst: jax.Array
    edge_w: jax.Array
    labels: jax.Array
    labeled_mask: jax.Array
    beta: jax.Array
    loss_scale: jax.Array
    grad_scale: jax.Array
    ell: Optional[ELLGraph] = None
    ti_scale: Optional[jax.Array] = None


def host_batch(sg: PaddedSubgraph, *, backend: str = "segment",
               ell_buckets=(8, 32, 128)) -> Batch:
    """Build a Batch of *host* (numpy) arrays, including the re-bucketed ELL
    adjacency for ``backend="ell"|"ti"`` — everything except the device
    transfer.

    This is the per-batch work the async pipeline runs on worker threads
    (pure numpy, no JAX calls, so workers never contend on device dispatch);
    the consumer moves the whole pytree over with one ``jax.device_put``
    (DESIGN.md §9). ``to_device_batch`` composes the two for the synchronous
    path. ``backend="ti"`` additionally rides the subgraph's α scales along
    (the halo-compensation transform — no store state needed at step time).
    """
    assert backend in AGG_BACKENDS, backend
    ell = None
    ti_scale = None
    if backend in ("ell", "ti"):
        ell = ell_from_coo(sg.edge_src, sg.edge_dst, sg.edge_w, sg.n_ext,
                           buckets=ell_buckets, as_jax=False)
    if backend == "ti":
        if sg.ti_scale is None:
            raise ValueError(
                'backend="ti" needs PaddedSubgraph.ti_scale; rebuild the '
                "subgraph with graph.structure.build_subgraph (any sampler "
                "batch has it)")
        ti_scale = np.asarray(sg.ti_scale)
    return Batch(
        batch_gids=np.asarray(sg.batch_gids), halo_gids=np.asarray(sg.halo_gids),
        batch_mask=np.asarray(sg.batch_mask), halo_mask=np.asarray(sg.halo_mask),
        edge_src=np.asarray(sg.edge_src), edge_dst=np.asarray(sg.edge_dst),
        edge_w=np.asarray(sg.edge_w), labels=np.asarray(sg.labels),
        labeled_mask=np.asarray(sg.labeled_mask), beta=np.asarray(sg.beta),
        loss_scale=np.asarray(sg.loss_scale), grad_scale=np.asarray(sg.grad_scale),
        ell=ell, ti_scale=ti_scale)


def to_device_batch(sg: PaddedSubgraph, *, backend: str = "segment",
                    ell_buckets=(8, 32, 128)) -> Batch:
    """Host subgraph -> device Batch (``host_batch`` + ``jax.device_put``)."""
    return jax.device_put(host_batch(sg, backend=backend,
                                     ell_buckets=ell_buckets))


def _combine(mode: str, beta: jax.Array, hist: jax.Array, fresh: jax.Array,
             mask: jax.Array) -> jax.Array:
    """Convex combination of historical and incomplete-fresh values (Eq. 9/12)."""
    if mode == "lmc":
        out = (1.0 - beta) * hist + beta * fresh
    elif mode == "historical":
        out = hist
    elif mode == "fresh":
        out = fresh
    elif mode == "none":
        out = jnp.zeros_like(fresh)
    else:
        raise ValueError(mode)
    return out * mask


def _compensate(mode: str, backend: str, store_l: Optional[jax.Array],
                halo_gids: jax.Array, beta1d: jax.Array, fresh: jax.Array,
                mask1d: jax.Array, stream: Optional[bool] = None,
                ti_scale: Optional[jax.Array] = None) -> jax.Array:
    """Halo compensation ĥ/V̂ (Eq. 9/12): gather the historical rows and
    convex-combine with the incomplete fresh values.

    backend="segment": jnp gather + lerp. backend="ell": one fused Pallas
    ``lmc_compensate`` call — every mode is the same kernel with an effective
    β (lmc: β, historical: 0, fresh: 1); "none" skips the gather entirely.
    ``stream`` (default: autodetect) selects the HBM→VMEM DMA store gather —
    the store is *full-graph* here, so the streamed path is what lets the
    compiled kernel run at paper scale (DESIGN.md §3).

    backend="ti": the message-invariance estimator (DESIGN.md §11) — the
    historical row H̄_i is replaced by the message-invariant transform
    α_i ⊙ h̃_i of the *in-batch* fresh value, so Eq. 9/12 collapse to an
    elementwise rescale ``((1-β_eff)·α + β_eff) ⊙ fresh`` with the same
    effective-β trick. No store read, no gather, no kernel: strictly less
    memory traffic than either store-reading backend.
    """
    if mode == "none":
        return jnp.zeros_like(fresh)
    if backend == "ti":
        beta_eff = {"lmc": beta1d,
                    "historical": jnp.zeros_like(beta1d),
                    "fresh": jnp.ones_like(beta1d)}[mode]
        coeff = (1.0 - beta_eff) * ti_scale + beta_eff
        return fresh * (coeff * mask1d)[:, None]
    if backend == "ell":
        beta_eff = {"lmc": beta1d,
                    "historical": jnp.zeros_like(beta1d),
                    "fresh": jnp.ones_like(beta1d)}[mode]
        return lmc_compensate(store_l, halo_gids, beta_eff, fresh, mask1d,
                              stream=stream)
    hist = gather_rows(store_l, halo_gids)
    return _combine(mode, beta1d[:, None], hist, fresh, mask1d[:, None])


def make_infer_step(gnn: GNN, num_nodes: int, *, backend: str = "segment",
                    fwd_mode: str = "historical", compensation: str = "store",
                    refresh: bool = True,
                    stream: Optional[bool] = None) -> Callable:
    """Build ``infer(params, store, batch, x_full, self_w_full)`` — the
    forward-only serving entry point over the historical store.

    Returns ``(logits, new_store)`` where ``logits`` covers the batch's
    padded target rows (mask with ``batch.batch_mask``). Pure; jit at call
    site, one trace per padded batch shape.

    The forward loop is the train step's (Eqs. 8-10) with the backward pass
    cut away: batch rows aggregate their *complete* neighborhood (every
    neighbor is in the padded extension), halo rows are approximated by
    ``_compensate``. Two axes:

    ``compensation="store"`` (the healthy serving path) gathers halo rows
    from ``store.h`` — with ``fwd_mode="historical"`` and a store holding
    exact layer values (core/exact.py ``exact_layer_values``), the target
    logits equal the full-graph forward exactly, at mini-batch cost: the
    store IS the receptive field. ``compensation="ti"`` substitutes the
    message-invariance transform α ⊙ fresh for every store read (DESIGN.md
    §11) — the store-free degraded mode with Fig.-3-bounded bias, also the
    repair path (recompute rows without trusting the store).

    ``refresh=True`` scatters the freshly computed batch rows back into the
    store (the read path through ``lmc_compensate`` under ``backend="ell"``);
    on the exact path this keeps refreshed rows exact, and under
    ``compensation="ti"`` it *heals* poisoned/stale rows from store-free
    values. ``refresh=False`` is the strictly read-only mode — with
    ``compensation="ti"`` the store is provably dead in the jaxpr.

    ``backend`` selects aggregation only ("segment" | "ell" Pallas SpMM);
    degradation swaps the compensation, never the aggregation kernel, so
    both modes share the compiled trace shape.
    """
    assert backend in ("segment", "ell"), backend
    assert compensation in ("store", "ti"), compensation
    assert fwd_mode in ("lmc", "historical", "fresh"), fwd_mode
    L = gnn.num_layers

    def infer(params: dict, store: HistoricalState, batch: Batch,
              x_full: jax.Array, self_w_full: jax.Array):
        nb = batch.batch_gids.shape[0]
        if backend == "ell" and batch.ell is None:
            raise ValueError(
                'backend="ell" needs batch.ell; build the batch with '
                'to_device_batch(sg, backend="ell")')
        if compensation == "ti" and batch.ti_scale is None:
            raise ValueError(
                'compensation="ti" needs batch.ti_scale; attach the '
                "subgraph's α scales (host_batch(sg, backend=\"ti\") or "
                "Batch._replace)")
        ext_gids = concat_rows([batch.batch_gids, batch.halo_gids])
        x_ext = jnp.take(x_full, ext_gids, axis=0, mode="clip")
        self_w_ext = jnp.take(self_w_full, ext_gids, axis=0, mode="clip")
        edges = EdgeList(batch.edge_src, batch.edge_dst, batch.edge_w)
        h0_ext = gnn.embed_apply(params["embed"], x_ext)
        aux = LayerAux(edges=edges, x=x_ext, h0=h0_ext, self_w=self_w_ext,
                       ell=batch.ell if backend == "ell" else None,
                       stream=stream)
        bmask = batch.batch_mask[:, None]
        comp_backend = "ti" if compensation == "ti" else backend

        h_in = h0_ext
        new_h = store.h
        for l in range(L):
            h_out = gnn.layer_apply(gnn.layer_params(params, l), l, h_in, aux)
            h_bar_batch = h_out[:nb] * bmask
            h_hat_halo = _compensate(
                fwd_mode, comp_backend,
                None if compensation == "ti" else new_h[l],
                batch.halo_gids, batch.beta, h_out[nb:], batch.halo_mask,
                stream, batch.ti_scale)
            if refresh:
                new_h = new_h.at[l].set(scatter_rows(
                    new_h[l], batch.batch_gids, batch.batch_mask, h_bar_batch,
                    num_nodes))
            h_in = concat_rows([h_bar_batch, h_hat_halo], axis=0)

        logits = gnn.head_apply(params["head"], h_in[:nb])
        return logits, HistoricalState(h=new_h, v=store.v)

    return infer


def make_train_step(gnn: GNN, method: MBMethod, num_nodes: int, *,
                    backend: str = "segment",
                    stream: Optional[bool] = None) -> Callable:
    """Build ``step(params, store, batch, x_full, self_w_full)``.

    Returns ``(loss, grads, new_store, metrics)``. Pure; jit/pjit at call site
    with ``donate_argnums=(1,)`` for the store.

    ``backend`` selects the aggregation hot path: ``"segment"`` is the jnp
    segment-sum oracle; ``"ell"`` runs layer aggregation through the Pallas
    bucketed ELL SpMM (forward *and*, via its custom VJP, the per-layer
    ``jax.vjp`` cotangent applications of Eqs. 11-13) and halo compensation
    through the fused ``lmc_compensate`` kernel. The batch must then carry the
    bucketed adjacency (``to_device_batch(sg, backend="ell")``).

    ``stream`` (ell/ti backends; default autodetect = streamed) selects the
    HBM→VMEM double-buffered DMA gather in both kernels — required for
    full-graph historical stores on the compiled path; ``stream=False``
    forces the legacy resident VMEM gather blocks.

    ``backend="ti"`` aggregates through the same Pallas SpMM but compensates
    with the message-invariance estimator instead of historical rows
    (DESIGN.md §11): the step performs *zero* reads of ``store.h``/``store.v``
    and — under a ``store_writes=False`` method like ``methods.TI`` — zero
    writes, returning the input store untouched.
    """
    method.validate()
    assert backend in AGG_BACKENDS, backend
    L = gnn.num_layers
    layer0_input_is_h0 = gnn.arch == "gcnii"

    def step(params: dict, store: HistoricalState, batch: Batch,
             x_full: jax.Array, self_w_full: jax.Array):
        nb = batch.batch_gids.shape[0]
        if backend in ("ell", "ti") and batch.ell is None:
            raise ValueError(
                f'backend="{backend}" needs batch.ell; build the batch with '
                f'to_device_batch(sg, backend="{backend}")')
        if backend == "ti" and batch.ti_scale is None:
            raise ValueError(
                'backend="ti" needs batch.ti_scale; build the batch with '
                'to_device_batch(sg, backend="ti")')
        # concat_rows (not jnp.concatenate): [batch | halo] row blocks must
        # keep explicit shardings under SPMD — see repro.dist.sharding
        ext_gids = concat_rows([batch.batch_gids, batch.halo_gids])
        x_ext = jnp.take(x_full, ext_gids, axis=0, mode="clip")
        self_w_ext = jnp.take(self_w_full, ext_gids, axis=0, mode="clip")
        edges = EdgeList(batch.edge_src, batch.edge_dst, batch.edge_w)
        h0_ext = gnn.embed_apply(params["embed"], x_ext)
        aux = LayerAux(edges=edges, x=x_ext, h0=h0_ext, self_w=self_w_ext,
                       ell=batch.ell if backend in ("ell", "ti") else None,
                       stream=stream)

        bmask = batch.batch_mask[:, None]
        hmask = batch.halo_mask[:, None]

        # ---------------- forward (Eqs. 8-10) --------------------------------
        h_in = h0_ext
        residuals = []
        new_h = store.h
        for l in range(L):
            residuals.append(h_in)
            h_out = gnn.layer_apply(gnn.layer_params(params, l), l, h_in, aux)
            h_bar_batch = h_out[:nb] * bmask
            # ti never touches the store — don't even slice it (keeps the
            # store inputs provably dead in the step's jaxpr)
            h_hat_halo = _compensate(method.fwd_mode, backend,
                                     None if backend == "ti" else new_h[l],
                                     batch.halo_gids, batch.beta, h_out[nb:],
                                     batch.halo_mask, stream, batch.ti_scale)
            if method.store_writes:
                new_h = new_h.at[l].set(scatter_rows(
                    new_h[l], batch.batch_gids, batch.batch_mask, h_bar_batch,
                    num_nodes))
            h_in = concat_rows([h_bar_batch, h_hat_halo], axis=0)

        # ---------------- loss & top-layer adjoints (Eq. 6/14 + V^L init) ----
        inv_vl = batch.loss_scale / batch.grad_scale  # = 1/|V_L|
        mask_b = batch.labeled_mask.at[nb:].set(0.0)
        mask_h = batch.labeled_mask.at[:nb].set(0.0)

        def unit_loss(head, h_rows, m):
            logits = gnn.head_apply(head, h_rows)
            logp = jax.nn.log_softmax(logits)
            ll = jnp.take_along_axis(logp, batch.labels[:, None], axis=-1)[:, 0]
            return -jnp.sum(ll * m) * inv_vl, logits

        (f1, logits_ext), vjp1 = jax.vjp(
            lambda hd, h: unit_loss(hd, h, mask_b), params["head"], h_in, has_aux=False)
        g_head_unit, V1 = vjp1((jnp.asarray(1.0, f1.dtype), jnp.zeros_like(logits_ext)))
        V_bar = V1[:nb] * bmask

        if method.bwd_mode == "none":
            V_hat = jnp.zeros_like(V1[nb:])
        else:
            (f2, _), vjp2 = jax.vjp(
                lambda h: unit_loss(params["head"], h, mask_h), h_in)
            (V2,) = vjp2((jnp.asarray(1.0, f1.dtype), jnp.zeros_like(logits_ext)))
            V_hat = V2[nb:] * hmask

        # ---------------- backward message passing (Eqs. 11-13, 7/15) --------
        grads_layers = [None] * L
        v0_acc = jnp.zeros_like(h0_ext)
        new_v = store.v
        for l in reversed(range(L)):
            lp = gnn.layer_params(params, l)

            def f(lp_, hin_, h0_, _l=l):
                return gnn.layer_apply(lp_, _l, hin_, aux._replace(h0=h0_))

            _, vjp_fn = jax.vjp(f, lp, residuals[l], h0_ext)
            ct_batch = concat_rows([V_bar, jnp.zeros_like(V_hat)], axis=0)
            g_lp, hgrad_b, h0grad_b = vjp_fn(ct_batch)
            grads_layers[l] = g_lp
            if method.bwd_mode == "none":
                hgrad, h0grad = hgrad_b, h0grad_b
            else:
                ct_full = concat_rows([V_bar, V_hat], axis=0)
                _, hgrad, h0grad = vjp_fn(ct_full)
            v0_acc = v0_acc + h0grad
            if l >= 1:
                V_bar_next = hgrad[:nb] * bmask
                V_hat = _compensate(method.bwd_mode, backend,
                                    None if backend == "ti" else new_v[l - 1],
                                    batch.halo_gids, batch.beta, hgrad[nb:],
                                    batch.halo_mask, stream, batch.ti_scale)
                if method.store_writes:
                    new_v = new_v.at[l - 1].set(scatter_rows(
                        new_v[l - 1], batch.batch_gids, batch.batch_mask,
                        V_bar_next, num_nodes))
                V_bar = V_bar_next
            elif layer0_input_is_h0:
                v0_acc = v0_acc + hgrad

        # ---------------- parameter gradients (Eq. 7 with A.3.1 scaling) -----
        scale = batch.grad_scale
        grads = {
            "layers": jax.tree.map(lambda *xs: [scale * x for x in xs],
                                   *grads_layers),
            "head": jax.tree.map(lambda x: scale * x, g_head_unit),
        }
        if params["embed"]:
            _, vjp_emb = jax.vjp(lambda e: gnn.embed_apply(e, x_ext), params["embed"])
            (g_emb,) = vjp_emb(v0_acc * concat_rows(
                [bmask, jnp.zeros_like(hmask)], axis=0))
            grads["embed"] = jax.tree.map(lambda x: scale * x, g_emb)
        else:
            grads["embed"] = {}

        # ---------------- metrics -------------------------------------------
        loss = f1 * scale
        pred = jnp.argmax(logits_ext[:nb], axis=-1)
        lab_b = mask_b[:nb]
        acc = jnp.sum((pred == batch.labels[:nb]) * lab_b) / jnp.maximum(
            jnp.sum(lab_b), 1.0)
        metrics = {"loss": loss, "train_acc": acc}
        return loss, grads, HistoricalState(h=new_h, v=new_v), metrics

    return step
