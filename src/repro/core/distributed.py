"""Distributed LMC: one cluster per device, compensation across the pod.

Mapping (DESIGN.md §4): per step every device trains on its own sampled
cluster; halo values come from the sharded historical stores. Mathematically
this is Algorithm 1 with batch = union of per-device clusters where
*cross-device* boundary messages are compensated (historical + incomplete
fresh) rather than exchanged fresh — the paper's own "sample more subgraphs to
build a large graph" mode, with the same convergence analysis.

Implementation: per-device padded subgraphs are **stacked host-side into one
flat batch** (row blocks per device, edge indices offset), so the flat batch
runs through the exact same `core.lmc.make_train_step`. Under `jit` with
`data`-axis shardings each device owns its row block; store reads/writes
become the halo-exchange collectives, visible in the dry-run HLO.

`spmd_shardings()` returns the in_shardings used by the launcher/dry-run.
"""
from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.lmc import Batch
from repro.dist import sharding as dist
from repro.graph.structure import PaddedSubgraph


def stack_batches(sgs: Sequence[PaddedSubgraph]) -> Batch:
    """Fuse per-device subgraphs into one flat Batch with remapped local ids.

    Row layout: [dev0 batch rows | dev1 batch rows | ...] then
                [dev0 halo rows | dev1 halo rows | ...].
    """
    nd = len(sgs)
    nb, nh = sgs[0].n_batch, sgs[0].n_halo
    for sg in sgs:
        assert sg.n_batch == nb and sg.n_halo == nh, "uniform padding required"

    def cat(attr):
        return np.concatenate([getattr(sg, attr) for sg in sgs])

    edge_src, edge_dst = [], []
    for d, sg in enumerate(sgs):
        src, dst = sg.edge_src.astype(np.int64), sg.edge_dst.astype(np.int64)
        src = np.where(src < nb, src + d * nb, nd * nb + d * nh + (src - nb))
        dst = np.where(dst < nb, dst + d * nb, nd * nb + d * nh + (dst - nb))
        edge_src.append(src.astype(np.int32))
        edge_dst.append(dst.astype(np.int32))

    labels = np.concatenate(
        [np.concatenate([sg.labels[:nb] for sg in sgs]),
         np.concatenate([sg.labels[nb:] for sg in sgs])])
    labeled = np.concatenate(
        [np.concatenate([sg.labeled_mask[:nb] for sg in sgs]),
         np.concatenate([sg.labeled_mask[nb:] for sg in sgs])])

    return Batch(
        batch_gids=jnp.asarray(cat("batch_gids")),
        halo_gids=jnp.asarray(cat("halo_gids")),
        batch_mask=jnp.asarray(cat("batch_mask")),
        halo_mask=jnp.asarray(cat("halo_mask")),
        edge_src=jnp.asarray(np.concatenate(edge_src)),
        edge_dst=jnp.asarray(np.concatenate(edge_dst)),
        edge_w=jnp.asarray(cat("edge_w")),
        labels=jnp.asarray(labels),
        labeled_mask=jnp.asarray(labeled),
        beta=jnp.asarray(cat("beta")),
        loss_scale=jnp.asarray(sgs[0].loss_scale / nd),
        grad_scale=jnp.asarray(sgs[0].grad_scale / nd),
    )


def spmd_shardings(mesh, *, model_axis: str | None = "model"):
    """(batch, store, x_full, self_w, params) shardings for the LMC step.

    Rows and stores shard along the data (and pod) axes; the feature dimension
    of the stores/activations shards along `model_axis` when wide enough. All
    specs derive from `repro.dist.sharding` — the same source the LM decode
    caches and the launcher use.
    """
    row = dist.row_sharding(mesh)
    rep = dist.replicated(mesh)
    batch_sh = Batch(
        batch_gids=row, halo_gids=row, batch_mask=row, halo_mask=row,
        edge_src=row, edge_dst=row, edge_w=row, labels=row, labeled_mask=row,
        beta=row, loss_scale=rep, grad_scale=rep,
    )
    store = dist.store_sharding(mesh, model_axis=model_axis)
    store_sh = {"h": store, "v": store}
    x_sh = dist.named(mesh, dist.dp_entry(mesh), None)
    sw_sh = row
    param_sh = rep  # replicated (GNN weights are small)
    return batch_sh, store_sh, x_sh, sw_sh, param_sh
