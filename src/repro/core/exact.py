"""Exact full-graph computation: ground-truth gradients and per-layer adjoints.

Provides (a) the full-batch GD baseline, (b) the exact per-node embeddings H^l
and auxiliary variables V^l = ∇_{H^l} L used by the backward-SGD estimators of
Section 4.2 (Thm 1 unbiasedness is property-tested against these), and (c) the
ground truth for the gradient-error experiments (paper Fig. 3).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.gnn import GNN, EdgeList, LayerAux


class FullGraphData(NamedTuple):
    x: jax.Array           # (n, dx)
    edges: EdgeList        # full symmetric edge list
    self_w: jax.Array      # (n,)
    labels: jax.Array      # (n,)
    labeled_mask: jax.Array  # (n,) f32 — train mask


def from_graph(graph) -> FullGraphData:
    """Build device-side full-graph data from a host Graph."""
    indptr, indices = graph.indptr, graph.indices
    row = np.repeat(np.arange(graph.num_nodes), np.diff(indptr)).astype(np.int64)
    w = graph.gcn_edge_weights(indices.astype(np.int64), row)
    deg = graph.degrees()
    return FullGraphData(
        x=jnp.asarray(graph.x),
        edges=EdgeList(src=jnp.asarray(indices.astype(np.int32)),
                       dst=jnp.asarray(row.astype(np.int32)),
                       w=jnp.asarray(w)),
        self_w=jnp.asarray((1.0 / (deg + 1.0)).astype(np.float32)),
        labels=jnp.asarray(graph.y.astype(np.int32)),
        labeled_mask=jnp.asarray(graph.train_mask.astype(np.float32)))


def full_loss(gnn: GNN, params: dict, data: FullGraphData) -> jax.Array:
    """L = (1/|V_L|) Σ_{labeled} ℓ(h_j, y_j) — Section 3.2's objective."""
    logits = gnn.full_forward(params, data.x, data.edges, data.self_w)
    logp = jax.nn.log_softmax(logits)
    ll = jnp.take_along_axis(logp, data.labels[:, None], axis=-1)[:, 0]
    return -jnp.sum(ll * data.labeled_mask) / jnp.maximum(
        jnp.sum(data.labeled_mask), 1.0)


def full_grads(gnn: GNN, params: dict, data: FullGraphData):
    """(loss, exact ∇L) by autodiff — the ground truth of Fig. 3."""
    return jax.value_and_grad(lambda p: full_loss(gnn, p, data))(params)


def accuracy(gnn: GNN, params: dict, data: FullGraphData, mask: jax.Array):
    logits = gnn.full_forward(params, data.x, data.edges, data.self_w)
    pred = jnp.argmax(logits, axis=-1)
    return jnp.sum((pred == data.labels) * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def exact_layer_values(gnn: GNN, params: dict, data: FullGraphData):
    """Exact H^l (l=1..L) and V^l (l=1..L-1) for the whole graph.

    These are the quantities backward SGD (Sec 4.2) assumes available; they
    also warm-start historical stores in tests.
    """
    L = gnn.num_layers
    h0 = gnn.embed_apply(params["embed"], data.x)
    aux = LayerAux(edges=data.edges, x=data.x, h0=h0, self_w=data.self_w)
    hs, residuals = [], []
    h = h0
    for l in range(L):
        residuals.append(h)
        h = gnn.layer_apply(gnn.layer_params(params, l), l, h, aux)
        hs.append(h)

    # top adjoint from the loss
    def head_loss(hL):
        logp = jax.nn.log_softmax(gnn.head_apply(params["head"], hL))
        ll = jnp.take_along_axis(logp, data.labels[:, None], axis=-1)[:, 0]
        return -jnp.sum(ll * data.labeled_mask) / jnp.maximum(
            jnp.sum(data.labeled_mask), 1.0)

    V = jax.grad(head_loss)(hs[-1])
    vs = [None] * L
    vs[L - 1] = V
    for l in reversed(range(1, L)):
        def f(hin_, _l=l):
            return gnn.layer_apply(gnn.layer_params(params, _l), _l, hin_, aux)
        _, vjp_fn = jax.vjp(f, residuals[l])
        (V,) = vjp_fn(V)
        vs[l - 1] = V
    return hs, vs


def backward_sgd_grads(gnn: GNN, params: dict, data: FullGraphData,
                       hs, vs, batch_nodes: jnp.ndarray, scale: float):
    """Eq. (7)/(15): θ-gradient estimate from exact values on a mini-batch.

    ``scale`` is b/c (App. A.3.1); with exact hs/vs these estimates are
    *unbiased* over uniform batch sampling (Thm 1) — property-tested.
    """
    L = gnn.num_layers
    n = data.x.shape[0]
    h0 = gnn.embed_apply(params["embed"], data.x)
    aux = LayerAux(edges=data.edges, x=data.x, h0=h0, self_w=data.self_w)
    sel = jnp.zeros((n,), jnp.float32).at[batch_nodes].set(1.0)
    grads = []
    for l in range(L):
        hin = h0 if l == 0 else hs[l - 1]
        def f(lp_, _l=l, _hin=hin):
            return gnn.layer_apply(lp_, _l, _hin, aux)
        _, vjp_fn = jax.vjp(f, gnn.layer_params(params, l))
        (g_lp,) = vjp_fn(vs[l] * sel[:, None])
        grads.append(jax.tree.map(lambda g: scale * g, g_lp))
    return jax.tree.map(lambda *xs: list(xs), *grads)
