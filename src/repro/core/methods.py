"""Mini-batch method space: LMC, GAS, Cluster-GCN and ablations as one config.

The unified train step (core/lmc.py) is parameterized by how halo (1-hop
out-of-batch) values are approximated in each direction:

  forward  ĥ = (1-β)·H̄(historical) + β·h̃(incomplete fresh)     (Eq. 9)
  backward V̂ = (1-β)·V̄(historical) + β·Ṽ(incomplete fresh)     (Eq. 12)

=> LMC        : fwd 'lmc',        bwd 'lmc'
   GAS        : fwd 'historical', bwd 'none'   (discard halo adjoints)
   Cluster-GCN: sampler drops the halo entirely (include_halo=False)
   C_f-only   : fwd 'lmc',        bwd 'none'   (Fig. 4 ablation)
   C_b-only   : fwd 'historical', bwd 'lmc'
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class MBMethod:
    name: str
    fwd_mode: str = "lmc"       # 'lmc' | 'historical' | 'fresh' | 'none'
    bwd_mode: str = "lmc"       # 'lmc' | 'none' | 'fresh'
    include_halo: bool = True   # sampler-level: False = Cluster-GCN view
    edge_weight_mode: str = "global"  # 'global' (GAS/LMC) | 'local' (Cluster)

    def validate(self) -> None:
        assert self.fwd_mode in ("lmc", "historical", "fresh", "none")
        assert self.bwd_mode in ("lmc", "none", "fresh")
        if not self.include_halo:
            assert self.fwd_mode == "none" and self.bwd_mode == "none"


LMC = MBMethod("lmc", fwd_mode="lmc", bwd_mode="lmc")
GAS = MBMethod("gas", fwd_mode="historical", bwd_mode="none")
CLUSTER = MBMethod("cluster", fwd_mode="none", bwd_mode="none",
                   include_halo=False, edge_weight_mode="local")
CF_ONLY = MBMethod("cf_only", fwd_mode="lmc", bwd_mode="none")
CB_ONLY = MBMethod("cb_only", fwd_mode="historical", bwd_mode="lmc")

METHODS = {m.name: m for m in (LMC, GAS, CLUSTER, CF_ONLY, CB_ONLY)}
