"""Mini-batch method space: LMC, GAS, Cluster-GCN, TI and ablations as one
config.

The unified train step (core/lmc.py) is parameterized by how halo (1-hop
out-of-batch) values are approximated in each direction:

  forward  ĥ = (1-β)·H̄(historical) + β·h̃(incomplete fresh)     (Eq. 9)
  backward V̂ = (1-β)·V̄(historical) + β·Ṽ(incomplete fresh)     (Eq. 12)

=> LMC        : fwd 'lmc',        bwd 'lmc'
   GAS        : fwd 'historical', bwd 'none'   (discard halo adjoints)
   Cluster-GCN: sampler drops the halo entirely (include_halo=False)
   C_f-only   : fwd 'lmc',        bwd 'none'   (Fig. 4 ablation)
   C_b-only   : fwd 'historical', bwd 'lmc'
   TI         : fwd 'lmc',        bwd 'lmc', store_writes=False — paired with
                ``make_train_step(..., backend="ti")``, which substitutes the
                message-invariant transform of in-batch messages for every
                H̄/V̄ read (arXiv 2502.19693; DESIGN.md §11). The estimator
                never reads the historical store, so the store refresh is
                pure waste and the method switches it off.

``store_writes`` controls the historical-store *refresh* path (the per-layer
scatter of fresh in-batch rows into H̄/V̄). It is orthogonal to the modes:
switching it off under a store-*reading* mode ('lmc'/'historical') freezes
the store at its initial contents rather than erroring — useful for
ablations, required for the store-free TI estimator.
"""
from __future__ import annotations

import dataclasses

# Thm 2's convergence bound tolerates a bias term geometric in the staleness ρ
# of every historical row read by a step. This is the one shared ρ-budget
# definition: the training tier (train/health.py HealthConfig.rho_budget) and
# the serving tier (serve/policy.py DegradationPolicy) must both read it so
# the two enforcement points cannot drift apart. Measured on the quickstart
# presets the realized ρ of cluster sampling stays well under this; rows past
# the budget are treated as unreliable (training: health event / strict error;
# serving: degrade the request to the store-free ti path).
RHO_BUDGET_DEFAULT = 64


@dataclasses.dataclass(frozen=True)
class MBMethod:
    name: str
    fwd_mode: str = "lmc"       # 'lmc' | 'historical' | 'fresh' | 'none'
    bwd_mode: str = "lmc"       # 'lmc' | 'none' | 'fresh'
    include_halo: bool = True   # sampler-level: False = Cluster-GCN view
    edge_weight_mode: str = "global"  # 'global' (GAS/LMC) | 'local' (Cluster)
    store_writes: bool = True   # refresh H̄/V̄ batch rows each step

    def validate(self) -> None:
        assert self.fwd_mode in ("lmc", "historical", "fresh", "none")
        assert self.bwd_mode in ("lmc", "none", "fresh")
        if not self.include_halo:
            assert self.fwd_mode == "none" and self.bwd_mode == "none"


LMC = MBMethod("lmc", fwd_mode="lmc", bwd_mode="lmc")
GAS = MBMethod("gas", fwd_mode="historical", bwd_mode="none")
CLUSTER = MBMethod("cluster", fwd_mode="none", bwd_mode="none",
                   include_halo=False, edge_weight_mode="local")
CF_ONLY = MBMethod("cf_only", fwd_mode="lmc", bwd_mode="none")
CB_ONLY = MBMethod("cb_only", fwd_mode="historical", bwd_mode="lmc")
TI = MBMethod("ti", fwd_mode="lmc", bwd_mode="lmc", store_writes=False)

METHODS = {m.name: m for m in (LMC, GAS, CLUSTER, CF_ONLY, CB_ONLY, TI)}
