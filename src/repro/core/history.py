"""Historical value stores (H̄^l and V̄^l of Section 5).

The stores are plain device arrays shaped ``(L, n, d)`` / ``(L-1, n, d)`` so
they can be sharded along the node axis on a mesh (``P(None, "data", None)``)
and threaded functionally (donated) through the train step. On the paper's
GPU setup these lived in host RAM with async transfers; on a TPU pod they
stay HBM-resident (see DESIGN.md §3).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class HistoricalState(NamedTuple):
    h: jax.Array  # (L, n, d)   historical embeddings  H̄^l, l = 1..L
    v: jax.Array  # (L-1, n, d) historical aux vars    V̄^l, l = 1..L-1

    @property
    def num_layers(self) -> int:
        return int(self.h.shape[0])


def init_history(num_layers: int, num_nodes: int, hidden_dim: int,
                 dtype=jnp.float32) -> HistoricalState:
    return HistoricalState(
        h=jnp.zeros((num_layers, num_nodes, hidden_dim), dtype),
        v=jnp.zeros((max(num_layers - 1, 1), num_nodes, hidden_dim), dtype),
    )


def scatter_rows(buf: jax.Array, gids: jax.Array, mask: jax.Array,
                 rows: jax.Array, n: int) -> jax.Array:
    """buf[gids] <- rows where mask==1; padded rows are dropped (index -> n)."""
    idx = jnp.where(mask > 0, gids, n).astype(jnp.int32)
    return buf.at[idx].set(rows, mode="drop")


def gather_rows(buf: jax.Array, gids: jax.Array) -> jax.Array:
    return jnp.take(buf, gids, axis=0, mode="clip")
