"""LMC core: the paper's primary contribution.

  history.py   — historical embedding / auxiliary-variable stores (H̄, V̄)
  methods.py   — LMC / GAS / Cluster-GCN / ablations as one config space
  lmc.py       — Algorithm 1: compensated forward + message-passing backward
  exact.py     — full-batch ground truth, exact adjoints, backward-SGD (Thm 1)
  distributed.py — pjit/shard_map multi-device LMC step (one cluster/device)
"""
from repro.core.history import HistoricalState, init_history
from repro.core.methods import (MBMethod, METHODS, LMC, GAS, CLUSTER, CF_ONLY,
                                CB_ONLY, TI, RHO_BUDGET_DEFAULT)
from repro.core.lmc import (Batch, host_batch, make_infer_step,
                            make_train_step, to_device_batch)
from repro.core.exact import (FullGraphData, from_graph, full_loss, full_grads,
                              accuracy, exact_layer_values, backward_sgd_grads)

__all__ = [
    "HistoricalState", "init_history", "MBMethod", "METHODS",
    "LMC", "GAS", "CLUSTER", "CF_ONLY", "CB_ONLY", "TI", "RHO_BUDGET_DEFAULT",
    "Batch", "host_batch", "make_infer_step", "make_train_step",
    "to_device_batch",
    "FullGraphData", "from_graph", "full_loss", "full_grads", "accuracy",
    "exact_layer_values", "backward_sgd_grads",
]
