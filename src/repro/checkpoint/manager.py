"""Atomic, resumable, reshardable checkpoints.

Layout: <dir>/step_<N>/   manifest.json  (treedef, shapes, dtypes, extras)
                          arr_<i>.npy    (one file per leaf)
        <dir>/step_<N>.tmp.*  while writing; os.replace makes publication
        atomic, so a crash mid-save never corrupts the latest checkpoint.

`reshard` re-places a restored tree under new shardings — the elastic-rescale
path (DESIGN.md §4): params/optimizer state reshard exactly; LMC historical
stores may alternatively be cold-reinitialized (staleness decays as ρ^k,
Thm 2), which `train.elastic.rescale_lmc_state` exploits.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


class CheckpointManager:
    def __init__(self, directory: str | Path, *, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, extras: Optional[dict] = None) -> Path:
        leaves, treedef = jax.tree.flatten(tree)
        final = self.dir / f"step_{step:010d}"
        tmp = Path(tempfile.mkdtemp(prefix=f"step_{step:010d}.tmp.",
                                    dir=self.dir))
        try:
            for i, leaf in enumerate(leaves):
                np.save(tmp / f"arr_{i}.npy", np.asarray(jax.device_get(leaf)))
            manifest = {
                "step": step,
                "num_leaves": len(leaves),
                "treedef": str(treedef),
                "extras": extras or {},
            }
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            if final.exists():
                shutil.rmtree(final)
            os.replace(tmp, final)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.iterdir():
            if p.is_dir() and p.name.startswith("step_") and \
                    (p / "manifest.json").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, target_tree: Any, step: Optional[int] = None
                ) -> tuple[Any, dict, int]:
        """Restore into the *structure* of target_tree (its leaves are only
        used for the treedef). Returns (tree, extras, step)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = self.dir / f"step_{step:010d}"
        manifest = json.loads((path / "manifest.json").read_text())
        _, treedef = jax.tree.flatten(target_tree)
        leaves = [np.load(path / f"arr_{i}.npy")
                  for i in range(manifest["num_leaves"])]
        return (jax.tree.unflatten(treedef, leaves), manifest["extras"],
                step)


def reshard(tree: Any, shardings: Any) -> Any:
    """Re-place a (host or device) tree under new shardings (elastic rescale
    across mesh changes: the restore path for a different device count)."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s), tree, shardings)
