"""Atomic, resumable, reshardable, *verifiable* checkpoints.

Layout: <dir>/step_<N>/   manifest.json  (treedef, per-leaf shape/dtype/crc32,
                                          extras)
                          arr_<i>.npy    (one file per leaf)
        <dir>/step_<N>.tmp.*  while writing; os.replace makes publication
        atomic, so a crash mid-save never corrupts the latest checkpoint.
        Orphaned tmp dirs left by hard crashes are GC'd on init and after
        every save.

Hardening (DESIGN.md §10):

* the manifest records per-leaf CRC32 checksums plus shape/dtype, and
  ``restore`` re-verifies every leaf while loading — a truncated/bit-flipped
  ``arr_*.npy`` or a mangled manifest surfaces as a :class:`CheckpointError`
  naming the step and leaf instead of a silently wrong tree;
* ``restore(step=None)`` walks checkpoints newest-first and returns the
  newest *verifiable* one, so a corrupt latest checkpoint costs one
  retention slot, not the run;
* ``save(..., background=True)`` snapshots the tree to host memory
  (``jax.device_get``) on the caller's thread, then writes + publishes on a
  single background writer thread — the training hot path only pays the
  device→host copy. Saves serialize (each waits for the previous one), and
  a background failure re-raises at the next ``save``/``wait``. Background
  and synchronous saves share one write path, so their bytes are identical.

`reshard` re-places a restored tree under new shardings — the elastic-rescale
path (DESIGN.md §4): params/optimizer state reshard exactly; LMC historical
stores may alternatively be cold-reinitialized (staleness decays as ρ^k,
Thm 2), which `train.elastic.rescale_lmc_state` exploits.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import zlib
from concurrent.futures import Future, ThreadPoolExecutor
from pathlib import Path
from typing import Any, Callable, Optional

import jax
import numpy as np

MANIFEST_FORMAT = 2   # 1 = pre-checksum manifests (still restorable)


class CheckpointError(RuntimeError):
    """A checkpoint is missing, structurally wrong, or fails verification."""


def crc32_array(arr: np.ndarray) -> int:
    """crc32 of an array's contiguous bytes — the manifest integrity idiom.

    Public so other tiers can reuse the exact same checksum definition; the
    serving store's per-row integrity ledger (serve/policy.py StoreIntegrity)
    records/verifies rows with this, keeping "corrupt" mean the same thing
    for a checkpoint leaf and a cached embedding row.
    """
    return zlib.crc32(np.ascontiguousarray(arr).tobytes())


_crc = crc32_array


class CheckpointManager:
    def __init__(self, directory: str | Path, *, keep: int = 3,
                 fault_hook: Optional[Callable[[int, str], None]] = None):
        """Open (creating if needed) a checkpoint directory.

        Args:
            directory: checkpoint root; one ``step_<N>/`` dir per step.
            keep: retention — older steps beyond the newest ``keep`` are GC'd.
            fault_hook: test-only injection point, called as
                ``hook(step, phase)`` before each leaf write
                (``phase="leaf_<i>"``) and before manifest publication
                (``"manifest"``); raising aborts the save, cleans the tmp
                dir and leaves the previous checkpoint untouched
                (``train.health.FaultPlan.ckpt_hook`` plugs in here).
        """
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.fault_hook = fault_hook
        self._pool: Optional[ThreadPoolExecutor] = None
        self._pending: Optional[Future] = None
        self._inflight_tmp: set = set()
        self._gc_orphans()   # tmp dirs left behind by a hard crash

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, extras: Optional[dict] = None, *,
             background: bool = False) -> Path:
        """Write an atomic checkpoint; returns its (eventual) directory.

        ``background=True`` snapshots the leaves to host numpy here (cheap
        device→host copy) and hands the file writes + atomic publication to
        a single writer thread, keeping disk latency off the training hot
        path. Saves serialize: a new save (or ``wait``/``restore``) first
        joins the previous one and re-raises its failure, so errors are
        never silently dropped. Both paths produce byte-identical files.
        """
        self.wait()   # serialize saves; surface a prior background failure
        leaves, treedef = jax.tree.flatten(tree)
        host = [np.asarray(jax.device_get(leaf)) for leaf in leaves]
        if not background:
            return self._write(step, host, str(treedef), extras or {})
        if self._pool is None:
            self._pool = ThreadPoolExecutor(max_workers=1,
                                            thread_name_prefix="ckpt-writer")
        self._pending = self._pool.submit(self._write, step, host,
                                          str(treedef), extras or {})
        return self.dir / f"step_{step:010d}"

    def wait(self) -> None:
        """Join the in-flight background save, re-raising its failure."""
        if self._pending is not None:
            fut, self._pending = self._pending, None
            fut.result()

    def close(self) -> None:
        """Join pending saves and stop the writer thread (idempotent)."""
        try:
            self.wait()
        finally:
            if self._pool is not None:
                self._pool.shutdown(wait=True)
                self._pool = None

    def _write(self, step: int, host_leaves: list, treedef_str: str,
               extras: dict) -> Path:
        """Synchronous write path shared by sync and background saves."""
        final = self.dir / f"step_{step:010d}"
        tmp = Path(tempfile.mkdtemp(prefix=f"step_{step:010d}.tmp.",
                                    dir=self.dir))
        self._inflight_tmp.add(tmp.name)
        try:
            leaf_meta = []
            for i, leaf in enumerate(host_leaves):
                if self.fault_hook is not None:
                    self.fault_hook(step, f"leaf_{i}")
                np.save(tmp / f"arr_{i}.npy", leaf)
                leaf_meta.append({"shape": list(leaf.shape),
                                  "dtype": str(leaf.dtype),
                                  "crc32": _crc(leaf)})
            manifest = {
                "format": MANIFEST_FORMAT,
                "step": step,
                "num_leaves": len(host_leaves),
                "treedef": treedef_str,
                "leaves": leaf_meta,
                "extras": extras,
            }
            if self.fault_hook is not None:
                self.fault_hook(step, "manifest")
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            if final.exists():
                shutil.rmtree(final)
            os.replace(tmp, final)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        finally:
            self._inflight_tmp.discard(tmp.name)
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)
        self._gc_orphans()

    def _gc_orphans(self) -> None:
        """Remove ``step_*.tmp.*`` dirs not owned by an in-flight save."""
        for p in self.dir.iterdir():
            if (p.is_dir() and p.name.startswith("step_")
                    and ".tmp." in p.name
                    and p.name not in self._inflight_tmp):
                shutil.rmtree(p, ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.iterdir():
            if p.is_dir() and p.name.startswith("step_") and \
                    ".tmp." not in p.name and \
                    (p / "manifest.json").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def verify(self, step: int) -> bool:
        """True iff checkpoint ``step`` exists and all leaves pass
        manifest shape/dtype/crc32 verification."""
        self.wait()
        try:
            self._load_verified(step, None, None)
        except CheckpointError:
            return False
        return True

    def restore(self, target_tree: Any, step: Optional[int] = None
                ) -> tuple[Any, dict, int]:
        """Restore into the *structure* of target_tree (its leaves are only
        used for the treedef). Returns (tree, extras, step).

        With ``step=None``, walks checkpoints newest-first and restores the
        newest one that passes verification — a corrupt/truncated latest
        checkpoint is skipped (with a notice on stdout), not fatal. With an
        explicit ``step``, verification failure raises
        :class:`CheckpointError` naming the step and the offending leaf.
        """
        self.wait()   # a pending background save must be visible (or fail)
        _, treedef = jax.tree.flatten(target_tree)
        if step is not None:
            leaves, manifest = self._load_verified(step, treedef.num_leaves,
                                                   str(treedef))
            return (jax.tree.unflatten(treedef, leaves), manifest["extras"],
                    step)
        steps = self.all_steps()
        if not steps:
            raise CheckpointError(f"no checkpoints in {self.dir}")
        failures = []
        for s in reversed(steps):
            try:
                leaves, manifest = self._load_verified(s, treedef.num_leaves,
                                                       str(treedef))
            except CheckpointError as e:
                failures.append(str(e))
                continue
            if failures:
                print(f"checkpoint: fell back to step {s} after skipping "
                      f"{len(failures)} unverifiable checkpoint(s): "
                      + " | ".join(failures), flush=True)
            return (jax.tree.unflatten(treedef, leaves), manifest["extras"],
                    s)
        raise CheckpointError(
            f"no verifiable checkpoint in {self.dir}: " + " | ".join(failures))

    def _load_verified(self, step: int, num_target_leaves: Optional[int],
                       target_treedef: Optional[str]) -> tuple[list, dict]:
        """Load + verify one checkpoint's leaves; CheckpointError on any
        missing/truncated/corrupt leaf or structural mismatch."""
        path = self.dir / f"step_{step:010d}"
        if not path.is_dir():
            raise CheckpointError(f"checkpoint step {step} not found "
                                  f"({path})")
        try:
            manifest = json.loads((path / "manifest.json").read_text())
        except FileNotFoundError:
            raise CheckpointError(
                f"checkpoint step {step}: manifest.json missing") from None
        except (OSError, json.JSONDecodeError, UnicodeDecodeError) as e:
            raise CheckpointError(
                f"checkpoint step {step}: unreadable manifest.json "
                f"({e})") from None
        n = manifest.get("num_leaves")
        if not isinstance(n, int) or n < 0:
            raise CheckpointError(
                f"checkpoint step {step}: invalid num_leaves {n!r}")
        if num_target_leaves is not None and n != num_target_leaves:
            raise CheckpointError(
                f"checkpoint step {step} holds {n} leaves but the target "
                f"tree expects {num_target_leaves} — wrong tree structure?")
        if target_treedef is not None and \
                manifest.get("treedef") not in (None, target_treedef):
            raise CheckpointError(
                f"checkpoint step {step}: tree structure mismatch "
                f"(saved {manifest.get('treedef')!r}, "
                f"target {target_treedef!r})")
        leaf_meta = manifest.get("leaves")   # absent in format-1 manifests
        if leaf_meta is not None and len(leaf_meta) != n:
            raise CheckpointError(
                f"checkpoint step {step}: manifest lists {len(leaf_meta)} "
                f"leaf records for num_leaves={n}")
        leaves = []
        for i in range(n):
            f = path / f"arr_{i}.npy"
            if not f.exists():
                raise CheckpointError(
                    f"checkpoint step {step}: missing leaf file {f.name} "
                    f"(have {n} leaves in the manifest)")
            try:
                arr = np.load(f)
            except Exception as e:   # truncated/corrupt npy headers vary
                raise CheckpointError(
                    f"checkpoint step {step}: leaf {f.name} unreadable "
                    f"(truncated?): {e}") from None
            if leaf_meta is not None:
                m = leaf_meta[i]
                if list(arr.shape) != list(m["shape"]) or \
                        str(arr.dtype) != m["dtype"]:
                    raise CheckpointError(
                        f"checkpoint step {step}: leaf {f.name} is "
                        f"{arr.dtype}{list(arr.shape)}, manifest says "
                        f"{m['dtype']}{m['shape']}")
                if _crc(arr) != m["crc32"]:
                    raise CheckpointError(
                        f"checkpoint step {step}: leaf {f.name} checksum "
                        f"mismatch (corrupt data)")
            leaves.append(arr)
        return leaves, manifest


def reshard(tree: Any, shardings: Any) -> Any:
    """Re-place a (host or device) tree under new shardings (elastic rescale
    across mesh changes: the restore path for a different device count)."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s), tree, shardings)
