from repro.checkpoint.manager import (CheckpointError, CheckpointManager,
                                      crc32_array, reshard)

__all__ = ["CheckpointError", "CheckpointManager", "crc32_array", "reshard"]
