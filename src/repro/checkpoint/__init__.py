from repro.checkpoint.manager import (CheckpointError, CheckpointManager,
                                      reshard)

__all__ = ["CheckpointError", "CheckpointManager", "reshard"]
