from repro.checkpoint.manager import CheckpointManager, reshard

__all__ = ["CheckpointManager", "reshard"]
