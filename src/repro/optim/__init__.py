from repro.optim.optimizers import (Optimizer, make_optimizer, sgd, adamw,
                                    adamw8bit, adafactor, global_norm_clip)
from repro.optim.spider import make_spider_controller
from repro.optim.compression import topk_compress, topk_decompress, int8_compress

__all__ = ["Optimizer", "make_optimizer", "sgd", "adamw", "adamw8bit",
           "adafactor", "global_norm_clip", "make_spider_controller",
           "topk_compress", "topk_decompress", "int8_compress"]
