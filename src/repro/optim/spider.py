"""LMC-SPIDER (paper Appendix F): variance-reduced mini-batch gradients.

Improves LMC's convergence from O(eps^-6) to O(eps^-3) by the stochastic
path-integrated differential estimator: every ``q`` steps take a large-batch
anchor gradient g_k = ∇L(W_k, S1); in between, update the running estimate

    g_k = ∇L(W_k, S2) - ∇L(W_{k-1}, S2) + g_{k-1}

on small batches S2 — the *same* batch evaluated at current and previous
params. The controller below is optimizer-agnostic: the trainer calls
``anchor()`` or ``refine()`` per Algorithm 2's schedule and descends along the
running estimate.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class SpiderState(NamedTuple):
    g_est: dict         # running gradient estimate (f32 tree)
    prev_params: dict   # W_{k-1}
    step: jax.Array


def make_spider_controller(q: int = 8):
    """Returns (init, should_anchor, anchor_update, refine_update)."""

    def init(params):
        z = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return SpiderState(g_est=z, prev_params=params, step=jnp.int32(0))

    def should_anchor(state: SpiderState) -> bool:
        return int(state.step) % q == 0

    def anchor_update(state: SpiderState, params, big_batch_grads):
        g = jax.tree.map(lambda x: x.astype(jnp.float32), big_batch_grads)
        return SpiderState(g_est=g, prev_params=params, step=state.step + 1)

    def refine_update(state: SpiderState, params, grads_at_current,
                      grads_at_prev):
        g = jax.tree.map(
            lambda ge, gc, gp: ge + gc.astype(jnp.float32) - gp.astype(jnp.float32),
            state.g_est, grads_at_current, grads_at_prev)
        return SpiderState(g_est=g, prev_params=params, step=state.step + 1)

    return init, should_anchor, anchor_update, refine_update
