"""Optimizers with spec-typed, fully shardable state.

Each optimizer exposes
  state_spec(param_spec_tree) -> PSpec tree   (so the dry-run can lower the
      whole train step without allocating anything, and state inherits the
      params' logical sharding)
  init(params) -> state
  update(grads, state, params) -> (new_params, new_state)

Implemented: SGD-momentum, AdamW (fp32 master + moments, ZeRO-sharded by
construction), AdamW-8bit (Dettmers-style block-quantized moments — used where
HBM is tight), Adafactor (factored second moment — the 671B config).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from repro.models.spec import PSpec

QBLOCK = 256  # block size for 8-bit moment quantization


def _is_spec(x):
    return isinstance(x, PSpec)


def global_norm_clip(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    state_spec: Callable
    update: Callable          # (grads, state, params, lr) -> (params, state)
    lr: float = 1e-3
    clip_norm: float = 1.0

    def init(self, params, param_spec) -> dict:
        return jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype),
            self.state_spec(param_spec), is_leaf=_is_spec)

    def abstract_state(self, param_spec):
        return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
                            self.state_spec(param_spec), is_leaf=_is_spec)


# --------------------------------------------------------------- SGD momentum
def _sgd_spec(pspec):
    mom = jax.tree.map(
        lambda s: PSpec(s.shape, s.logical, init="zeros", dtype=jnp.float32),
        pspec, is_leaf=_is_spec)
    return {"mom": mom, "count": PSpec((), (), init="zeros", dtype=jnp.int32)}


def _sgd_update(grads, state, params, lr, *, beta=0.9, clip=1.0):
    g32, gn = global_norm_clip(grads, clip)
    mom = jax.tree.map(lambda m, g: beta * m + g, state["mom"], g32)
    new_p = jax.tree.map(lambda p, m: (p.astype(jnp.float32) - lr * m)
                         .astype(p.dtype), params, mom)
    return new_p, {"mom": mom, "count": state["count"] + 1}, gn


# -------------------------------------------------------------------- AdamW
def _adamw_spec(pspec):
    f32 = lambda s: PSpec(s.shape, s.logical, init="zeros", dtype=jnp.float32)
    return {
        "m": jax.tree.map(f32, pspec, is_leaf=_is_spec),
        "v": jax.tree.map(f32, pspec, is_leaf=_is_spec),
        "master": jax.tree.map(f32, pspec, is_leaf=_is_spec),
        "count": PSpec((), (), init="zeros", dtype=jnp.int32),
    }


def _adamw_update(grads, state, params, lr, *, b1=0.9, b2=0.95, eps=1e-8,
                  wd=0.1, clip=1.0):
    g32, gn = global_norm_clip(grads, clip)
    cnt = state["count"] + 1
    t = cnt.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], g32)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], g32)
    # master==0 at step 1 means "adopt current params" (init-free warm start)
    master = jax.tree.map(
        lambda ms, p: jnp.where(cnt == 1, p.astype(jnp.float32), ms),
        state["master"], params)
    master = jax.tree.map(
        lambda ms, m_, v_: ms - lr * ((m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
                                      + wd * ms),
        master, m, v)
    new_p = jax.tree.map(lambda ms, p: ms.astype(p.dtype), master, params)
    return new_p, {"m": m, "v": v, "master": master, "count": cnt}, gn


# --------------------------------------------------------------- AdamW 8-bit
def _q8_scale_shape(shape):
    if not shape:
        return (1,)
    last = shape[-1]
    return tuple(shape[:-1]) + (max(1, (last + QBLOCK - 1) // QBLOCK),)


def _adamw8_spec(pspec):
    def q8(s):
        return PSpec(s.shape, s.logical, init="zeros", dtype=jnp.int8)

    def sc(s):
        return PSpec(_q8_scale_shape(s.shape),
                     tuple(s.logical[:-1]) + (None,) if s.shape else (None,),
                     init="zeros", dtype=jnp.float32)

    f32 = lambda s: PSpec(s.shape, s.logical, init="zeros", dtype=jnp.float32)
    return {
        "m_q": jax.tree.map(q8, pspec, is_leaf=_is_spec),
        "m_s": jax.tree.map(sc, pspec, is_leaf=_is_spec),
        "v_q": jax.tree.map(q8, pspec, is_leaf=_is_spec),
        "v_s": jax.tree.map(sc, pspec, is_leaf=_is_spec),
        "master": jax.tree.map(f32, pspec, is_leaf=_is_spec),
        "count": PSpec((), (), init="zeros", dtype=jnp.int32),
    }


def _q8_encode(x):
    shape = x.shape
    if not shape:
        x = x[None]
        shape = (1,)
    last = shape[-1]
    pad = (-last) % QBLOCK
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    xb = xp.reshape(*shape[:-1], -1, QBLOCK)
    s = jnp.max(jnp.abs(xb), axis=-1) / 127.0
    q = jnp.round(xb / jnp.maximum(s, 1e-12)[..., None]).astype(jnp.int8)
    return q.reshape(*shape[:-1], -1)[..., :last], s


def _q8_decode(q, s, shape):
    last = shape[-1] if shape else 1
    pad = (-last) % QBLOCK
    qp = jnp.pad(q, [(0, 0)] * (q.ndim - 1) + [(0, pad)])
    xb = qp.reshape(*q.shape[:-1], -1, QBLOCK).astype(jnp.float32)
    out = (xb * s[..., None]).reshape(*q.shape[:-1], -1)[..., :last]
    return out.reshape(shape)


def _adamw8_update(grads, state, params, lr, *, b1=0.9, b2=0.95, eps=1e-8,
                   wd=0.1, clip=1.0):
    g32, gn = global_norm_clip(grads, clip)
    cnt = state["count"] + 1
    t = cnt.astype(jnp.float32)
    bc1, bc2 = 1.0 - b1 ** t, 1.0 - b2 ** t

    def upd(p, g, mq, ms, vq, vs, master):
        m = b1 * _q8_decode(mq, ms, p.shape) + (1 - b1) * g
        v = b2 * _q8_decode(vq, vs, p.shape) + (1 - b2) * g * g
        mst = jnp.where(cnt == 1, p.astype(jnp.float32), master)
        mst = mst - lr * ((m / bc1) / (jnp.sqrt(v / bc2) + eps) + wd * mst)
        mq2, ms2 = _q8_encode(m)
        vq2, vs2 = _q8_encode(v)
        return mst.astype(p.dtype), mq2, ms2, vq2, vs2, mst

    flat_p, td = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(g32)
    flat_mq = jax.tree.leaves(state["m_q"])
    flat_ms = jax.tree.leaves(state["m_s"])
    flat_vq = jax.tree.leaves(state["v_q"])
    flat_vs = jax.tree.leaves(state["v_s"])
    flat_ma = jax.tree.leaves(state["master"])
    outs = [upd(*args) for args in zip(flat_p, flat_g, flat_mq, flat_ms,
                                       flat_vq, flat_vs, flat_ma)]
    unz = list(zip(*outs))
    mk = lambda i: jax.tree.unflatten(td, list(unz[i]))
    return mk(0), {"m_q": mk(1), "m_s": mk(2), "v_q": mk(3), "v_s": mk(4),
                   "master": mk(5), "count": cnt}, gn


# ------------------------------------------------------------------ Adafactor
def _adafactor_spec(pspec):
    def vr(s):
        if len(s.shape) >= 2:
            return PSpec(s.shape[:-1], s.logical[:-1], init="zeros",
                         dtype=jnp.float32)
        return PSpec(s.shape, s.logical, init="zeros", dtype=jnp.float32)

    def vc(s):
        if len(s.shape) >= 2:
            return PSpec(s.shape[:-2] + s.shape[-1:],
                         s.logical[:-2] + s.logical[-1:], init="zeros",
                         dtype=jnp.float32)
        return PSpec((1,), (None,), init="zeros", dtype=jnp.float32)

    return {
        "vr": jax.tree.map(vr, pspec, is_leaf=_is_spec),
        "vc": jax.tree.map(vc, pspec, is_leaf=_is_spec),
        "count": PSpec((), (), init="zeros", dtype=jnp.int32),
    }


def _sq_einsum(g, axis: int):
    """Σ g² reduced over one axis — einsum with f32 accumulation, so the
    bf16 gradient never materializes as an f32 copy (CPU XLA fusion is weak;
    explicit dots keep the 671B leaves from blowing up the arena)."""
    letters = "abcdefghij"[:g.ndim]
    out = letters.replace(letters[axis], "")
    return jnp.einsum(f"{letters},{letters}->{out}", g, g,
                      preferred_element_type=jnp.float32)


def _adafactor_update(grads, state, params, lr, *, decay=0.8, eps=1e-30,
                      clip=1.0, wd=0.0, stream_bytes=1 << 27):
    """Memory-lean Adafactor.

    * global-norm clip folded into the per-leaf update (no f32 grad-tree copy)
    * factored second-moment stats computed with f32-accumulating einsums
    * big leaves (>= stream_bytes f32) take a broadcast-elementwise update
      path without the relative-RMS clip (the global clip still applies) —
      this keeps per-leaf f32 temporaries fused on the CPU backend too.
    """
    def leaf_sq(g):
        # contract over all axes in place — a reshape(-1) of a sharded leaf
        # would force GSPMD to all-gather it (observed: +5.7 TiB on 671B)
        letters = "abcdefghij"[:g.ndim]
        return jnp.einsum(f"{letters},{letters}->", g, g,
                          preferred_element_type=jnp.float32)

    gn = jnp.sqrt(sum(leaf_sq(g) for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, clip / jnp.maximum(gn, 1e-9))
    cnt = state["count"] + 1
    t = cnt.astype(jnp.float32)
    beta = 1.0 - t ** (-decay)

    def upd(p, g, vr, vc):
        if g.ndim >= 2:
            s2 = scale * scale
            vr2 = beta * vr + (1 - beta) * (s2 * _sq_einsum(g, g.ndim - 1)
                                            / g.shape[-1] + eps)
            vc2 = beta * vc + (1 - beta) * (s2 * _sq_einsum(g, g.ndim - 2)
                                            / g.shape[-2] + eps)
            denom = jnp.maximum(jnp.mean(vr2, axis=-1, keepdims=True), eps)
            r_fac = jax.lax.rsqrt(jnp.maximum(vr2 / denom, eps))[..., None]
            c_fac = jax.lax.rsqrt(jnp.maximum(vc2, eps))[..., None, :]
            u = g.astype(jnp.float32) * scale * r_fac * c_fac
            rms_u = jnp.sqrt(jnp.mean(u * u) + 1e-12)
            u = u / jnp.maximum(1.0, rms_u)
            newp = ((1.0 - lr * wd) * p.astype(jnp.float32) - lr * u)
            return newp.astype(p.dtype), vr2, vc2
        vr2 = beta * vr + (1 - beta) * (scale * scale * g.astype(jnp.float32) ** 2
                                        + eps)
        u = g.astype(jnp.float32) * scale * jax.lax.rsqrt(jnp.maximum(vr2, eps))
        rms_u = jnp.sqrt(jnp.mean(u * u) + 1e-12)
        u = u / jnp.maximum(1.0, rms_u)
        newp = (1.0 - lr * wd) * p.astype(jnp.float32) - lr * u
        return newp.astype(p.dtype), vr2, vc

    def upd_leaf(p, g, vr, vc):
        """Stream big leaves so f32 temporaries stay slice-sized (the CPU
        backend materializes each elementwise op of a 3.4 GiB chain)."""
        if p.size * 4 <= stream_bytes:
            return upd(p, g, vr, vc)
        if p.ndim >= 3:
            # layer-stacked leaf: per-layer slices are exact (stats factor
            # along the leading axis); relative-RMS clip becomes per-layer.
            return jax.lax.map(lambda a: upd(*a), (p, g, vr, vc))
        # big 2-D leaf (embedding/head): chunk the row axis; vc (column
        # stats) from equal-chunk means stays exact, rms clip is per-chunk.
        rows = p.shape[0]
        chunks = 1
        for c in (64, 32, 16, 8, 4, 2):
            if rows % c == 0 and p.size * 4 // c <= stream_bytes:
                chunks = c
                break
        rs = lambda a: a.reshape(chunks, rows // chunks, *a.shape[1:])
        vc_parts = jax.lax.map(
            lambda a: _sq_einsum(a, 0) / a.shape[0], rs(g))
        vc2 = beta * vc + (1 - beta) * (scale * scale * vc_parts.mean(0) + eps)

        def chunk_upd(a):
            pc, gc, vrc = a
            vr2c = beta * vrc + (1 - beta) * (scale * scale
                                              * _sq_einsum(gc, 1)
                                              / gc.shape[-1] + eps)
            return vr2c, pc, gc

        # two passes: (1) vr per chunk, (2) update with the global denom
        vr2 = jax.lax.map(lambda a: chunk_upd(a)[0], (rs(p), rs(g), rs(vr)))
        denom = jnp.maximum(jnp.mean(vr2), eps)

        def chunk_new(a):
            pc, gc, vr2c = a
            r_fac = jax.lax.rsqrt(jnp.maximum(vr2c / denom, eps))[..., None]
            c_fac = jax.lax.rsqrt(jnp.maximum(vc2, eps))[None, :]
            u = gc.astype(jnp.float32) * scale * r_fac * c_fac
            rms_u = jnp.sqrt(jnp.mean(u * u) + 1e-12)
            u = u / jnp.maximum(1.0, rms_u)
            return ((1.0 - lr * wd) * pc.astype(jnp.float32)
                    - lr * u).astype(pc.dtype)

        newp = jax.lax.map(chunk_new, (rs(p), rs(g), vr2))
        return newp.reshape(p.shape), vr2.reshape(vr.shape), vc2

    flat_p, td = jax.tree.flatten(params)
    outs = [upd_leaf(p, g, vr, vc) for p, g, vr, vc in zip(
        flat_p, jax.tree.leaves(grads), jax.tree.leaves(state["vr"]),
        jax.tree.leaves(state["vc"]))]
    unz = list(zip(*outs))
    mk = lambda i: jax.tree.unflatten(td, list(unz[i]))
    return mk(0), {"vr": mk(1), "vc": mk(2), "count": cnt}, gn


# -------------------------------------------------------------------- factory
def sgd(lr=1e-2, **kw):
    return Optimizer("sgd", _sgd_spec, partial(_sgd_update, **kw), lr=lr)


def adamw(lr=3e-4, **kw):
    return Optimizer("adamw", _adamw_spec, partial(_adamw_update, **kw), lr=lr)


def adamw8bit(lr=3e-4, **kw):
    return Optimizer("adamw8bit", _adamw8_spec, partial(_adamw8_update, **kw),
                     lr=lr)


def adafactor(lr=1e-2, **kw):
    return Optimizer("adafactor", _adafactor_spec,
                     partial(_adafactor_update, **kw), lr=lr)


def make_optimizer(name: str, lr: float | None = None) -> Optimizer:
    table = {"sgd": sgd, "adamw": adamw, "adamw8bit": adamw8bit,
             "adafactor": adafactor}
    opt = table[name]()
    if lr is not None:
        opt = dataclasses.replace(opt, lr=lr)
    return opt
