"""Gradient compression for cross-pod reduction (distributed-optimization trick).

Top-k sparsification with error feedback (Stich et al. 2018) and int8
quantization. Used by the trainer when `grad_compression` is enabled: local
gradients are compressed before the (slow, cross-pod DCN) all-reduce and the
residual is fed back into the next step — the pod-internal (fast, ICI)
reduction stays exact.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class TopKPayload(NamedTuple):
    values: jax.Array
    indices: jax.Array
    shape: tuple


def topk_compress(g: jax.Array, frac: float = 0.01,
                  error: jax.Array | None = None):
    """Keep the top `frac` entries by magnitude; return payload + new error."""
    flat = g.astype(jnp.float32).reshape(-1)
    if error is not None:
        flat = flat + error.reshape(-1)
    k = max(1, int(flat.shape[0] * frac))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    picked = flat[idx]
    new_error = flat.at[idx].set(0.0).reshape(g.shape)
    return TopKPayload(values=picked, indices=idx, shape=g.shape), new_error


def topk_decompress(payload: TopKPayload) -> jax.Array:
    n = 1
    for s in payload.shape:
        n *= s
    out = jnp.zeros((n,), jnp.float32).at[payload.indices].set(payload.values)
    return out.reshape(payload.shape)


def int8_compress(g: jax.Array):
    """Symmetric per-tensor int8 quantization (returns q, scale)."""
    scale = jnp.max(jnp.abs(g.astype(jnp.float32))) / 127.0
    q = jnp.round(g.astype(jnp.float32) / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return q, scale


def int8_decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale
