"""LM assembly: configs -> (param specs, train/prefill/decode fns).

A model is a list of **segments**; each segment is `count` repeats of a block
pattern whose per-layer params are stacked on a leading axis and executed with
`lax.scan` (compile-time O(1) in depth — mandatory for 100-layer archs on this
container's single-core XLA). Two build knobs exist purely for the roofline
harness (EXPERIMENTS.md §Roofline):

  depth_profile: {segment_name: count}  — shrink depth per segment, so per-layer
      marginal FLOPs/bytes can be measured exactly from compiled artifacts
      (cost_analysis does NOT multiply scan-body costs by trip count — verified);
  unroll=True — Python-loop the segments (and disable attention KV-chunking)
      in those cost-extraction builds so nothing hides inside a while-loop.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist.sharding import concat_rows, shard_act, shard_res
from repro.models import blocks as B
from repro.models import ssm as S
from repro.models.blocks import Ctx
from repro.models.layers import (softmax_cross_entropy, rms_norm,
                                 embed_lookup, BF16)
from repro.models.spec import PSpec, abstract, materialize

VOCAB_ALIGN = 2048


def _pad_vocab(v: int) -> int:
    return ((v + VOCAB_ALIGN - 1) // VOCAB_ALIGN) * VOCAB_ALIGN


def _stack(spec_tree, count: int):
    return jax.tree.map(
        lambda s: PSpec((count,) + s.shape, ("layers",) + s.logical,
                        init=s.init, scale=s.scale, dtype=s.dtype),
        spec_tree, is_leaf=lambda x: isinstance(x, PSpec))


@dataclasses.dataclass(frozen=True)
class Segment:
    name: str
    kind: str
    count: int
    layer_spec: dict      # one layer's PSpec tree (unstacked)
    inner: int = 1        # inner python-loop repeats inside one scanned step


class LM:
    """A built language model: specs + pure apply fns for one ArchConfig."""

    def __init__(self, cfg: ArchConfig, *,
                 depth_profile: Optional[dict[str, int]] = None,
                 unroll: bool = False):
        self.cfg = cfg
        self.unroll = unroll
        self.vpad = _pad_vocab(cfg.vocab)
        self.segments = self._plan_segments(cfg, depth_profile or {})
        if unroll:
            # cost-extraction build: nothing may hide inside a while loop
            kw = {"attn_chunk": 1 << 30}
            if cfg.moe is not None:
                kw["moe"] = dataclasses.replace(cfg.moe, dispatch_chunks=1)
            self.cfg = dataclasses.replace(cfg, **kw)

    # ------------------------------------------------------------ planning
    @staticmethod
    def _plan_segments(cfg: ArchConfig, prof: dict[str, int]) -> list[Segment]:
        segs: list[Segment] = []

        def n(name, default):
            return max(int(prof.get(name, default)), 0)

        if cfg.family == "dense":
            segs.append(Segment("blocks", "dense", n("blocks", cfg.n_layers),
                                {"attn": B.attn_spec(cfg), "mlp": B.mlp_spec(cfg)}))
        elif cfg.family == "moe":
            fd = cfg.moe.first_dense_layers
            attn_spec = B.mla_spec(cfg) if cfg.mla else B.attn_spec(cfg)
            if fd:
                segs.append(Segment(
                    "dense_blocks", "moe_dense", n("dense_blocks", fd),
                    {"attn": dict(attn_spec),
                     "mlp": B.mlp_spec(cfg, cfg.moe.d_ff_dense)}))
            segs.append(Segment(
                "moe_blocks", "moe", n("moe_blocks", cfg.n_layers - fd),
                {"attn": dict(attn_spec), "moe": B.moe_spec(cfg)}))
        elif cfg.family == "ssm":
            segs.append(Segment("blocks", "rwkv", n("blocks", cfg.n_layers),
                                S.rwkv6_spec(cfg)))
        elif cfg.family == "hybrid":
            groups, tail = divmod(cfg.n_layers, cfg.attn_every)
            segs.append(Segment(
                "groups", "mamba_group", n("groups", groups),
                {"mamba": _stack(S.mamba2_spec(cfg), cfg.attn_every)},
                inner=cfg.attn_every))
            if tail:
                segs.append(Segment("tail", "mamba", n("tail", tail),
                                    S.mamba2_spec(cfg)))
        elif cfg.family == "vlm":
            g = cfg.cross_every
            n_cross = cfg.n_layers // g
            segs.append(Segment(
                "groups", "vlm_group", n("groups", n_cross),
                {"self": _stack({"attn": B.attn_spec(cfg),
                                 "mlp": B.mlp_spec(cfg)}, g - 1),
                 "cross": {"attn": B.cross_attn_spec(cfg),
                           "mlp": B.mlp_spec(cfg)}},
                inner=g - 1))
        elif cfg.family == "encdec":
            segs.append(Segment("encoder", "enc", n("encoder", cfg.enc_layers),
                                {"attn": B.attn_spec(cfg), "mlp": B.mlp_spec(cfg)}))
            segs.append(Segment(
                "decoder", "dec", n("decoder", cfg.dec_layers),
                {"attn": B.attn_spec(cfg), "cross": B.cross_attn_spec(cfg),
                 "mlp": B.mlp_spec(cfg)}))
        else:
            raise ValueError(cfg.family)
        return segs

    # -------------------------------------------------------------- params
    def params_spec(self) -> dict:
        cfg = self.cfg
        d = cfg.d_model
        spec: dict[str, Any] = {
            "embed": PSpec((self.vpad, d), ("vocab", "embed"), scale=0.01),
            "final_ln": PSpec((d,), ("embed",), init="ones"),
        }
        if not cfg.tie_embeddings:
            spec["head"] = PSpec((d, self.vpad), ("embed", "vocab"), scale=0.01)
        for seg in self.segments:
            spec[seg.name] = _stack(seg.layer_spec, seg.count)
        if cfg.shared_attn:
            spec["shared_attn"] = {"attn": B.attn_spec(cfg),
                                   "mlp": B.mlp_spec(cfg)}
        if cfg.mtp_depth:
            spec["mtp"] = {"proj": PSpec((2 * d, d), (None, "embed")),
                           "ln": PSpec((d,), ("embed",), init="ones"),
                           "attn": (B.mla_spec(cfg) if cfg.mla
                                    else B.attn_spec(cfg)),
                           "mlp": B.mlp_spec(cfg, cfg.d_ff or 4 * d)}
        return spec

    def init_params(self, rng: jax.Array) -> dict:
        return materialize(self.params_spec(), rng)

    def abstract_params(self) -> dict:
        return abstract(self.params_spec())

    # ------------------------------------------------------------ helpers
    def _remat(self, fn):
        if self.cfg.remat == "none" or self.unroll:
            return fn
        if self.cfg.remat == "dots":
            return jax.checkpoint(
                fn, policy=jax.checkpoint_policies.checkpoint_dots)
        return jax.checkpoint(fn)

    def _run_seg(self, body, h, xs_tree, count):
        """scan (or python-loop when unrolling) body over stacked params."""
        if self.unroll:
            for i in range(count):
                x_i = jax.tree.map(lambda a: a[i], xs_tree)
                h, _ = body(h, x_i)
            return h
        h, _ = jax.lax.scan(self._remat(body), h, xs_tree)
        return h

    def _block_body(self, seg: Segment, params: dict, ctx: Ctx):
        cfg = self.cfg
        kind = seg.kind

        def dense(h, lp):
            h = B.attn_apply(lp["attn"], h, ctx, cfg)
            return B.mlp_apply(lp["mlp"], h, cfg), None

        def moe_dense(h, lp):
            h = (B.mla_apply if cfg.mla else B.attn_apply)(lp["attn"], h, ctx, cfg)
            return B.mlp_apply(lp["mlp"], h, cfg), None

        def moe(h, lp):
            h = (B.mla_apply if cfg.mla else B.attn_apply)(lp["attn"], h, ctx, cfg)
            return B.moe_apply(lp["moe"], h, cfg), None

        def rwkv(h, lp):
            h, _, _, _ = S.rwkv6_apply(lp, h, cfg)
            return h, None

        def mamba(h, lp):
            return S.mamba2_apply(lp, h, cfg), None

        def mamba_group(h, lp):
            for i in range(seg.inner):
                mp = jax.tree.map(lambda a: a[i], lp["mamba"])
                h = S.mamba2_apply(mp, h, cfg)
            sp = params["shared_attn"]
            h = B.attn_apply(sp["attn"], h, ctx, cfg)
            h = B.mlp_apply(sp["mlp"], h, cfg)
            return h, None

        def vlm_group(h, lp):
            for i in range(seg.inner):
                sl = jax.tree.map(lambda a: a[i], lp["self"])
                h = B.attn_apply(sl["attn"], h, ctx, cfg)
                h = B.mlp_apply(sl["mlp"], h, cfg)
            h = B.cross_attn_apply(lp["cross"]["attn"], h, ctx, cfg)
            h = B.mlp_apply(lp["cross"]["mlp"], h, cfg)
            return h, None

        def enc(h, lp):
            h = B.attn_apply(lp["attn"], h, ctx, cfg, causal=False)
            return B.mlp_apply(lp["mlp"], h, cfg), None

        def dec(h, lp):
            h = B.attn_apply(lp["attn"], h, ctx, cfg)
            h = B.cross_attn_apply(lp["cross"], h, ctx, cfg)
            return B.mlp_apply(lp["mlp"], h, cfg), None

        return {"dense": dense, "moe_dense": moe_dense, "moe": moe,
                "rwkv": rwkv, "mamba": mamba, "mamba_group": mamba_group,
                "vlm_group": vlm_group, "enc": enc, "dec": dec}[kind]

    # ------------------------------------------------------- forward (train)
    def _backbone(self, params: dict, h: jax.Array, ctx: Ctx,
                  seg_filter=None) -> jax.Array:
        for seg in self.segments:
            if seg_filter and seg.name not in seg_filter:
                continue
            if seg.count == 0:
                continue
            body = self._block_body(seg, params, ctx)
            h = self._run_seg(body, h, params[seg.name], seg.count)
        return h

    def _logits(self, params: dict, h: jax.Array) -> jax.Array:
        h = rms_norm(h, params["final_ln"], self.cfg.norm_eps)
        w = params["embed"].T if self.cfg.tie_embeddings else params["head"]
        # vocab stays model-sharded: the head/embed gradient contraction then
        # produces (d, vpad/n_model) partials instead of full (d, vpad) f32
        # buffers per device (EXPERIMENTS.md §Dry-run, 671B case study)
        h = shard_act(h, "dp", None, None)
        return shard_act(jnp.einsum("bsd,dv->bsv", h, w), "dp", None, "model")

    def train_loss(self, params: dict, batch: dict) -> jax.Array:
        """batch: tokens (B,S) int32, loss_mask (B,S) f32 [, memory (B,T,d)]."""
        cfg = self.cfg
        tokens = batch["tokens"]
        bsz, seq = tokens.shape
        pos = jax.lax.broadcasted_iota(jnp.int32, (bsz, seq), 1)
        ctx = Ctx(positions=pos, length=jnp.int32(0),
                  memory=batch.get("memory"))
        h = shard_res(embed_lookup(params["embed"], tokens))

        if cfg.family == "encdec":
            src = batch["memory"]
            src_pos = jax.lax.broadcasted_iota(
                jnp.int32, (src.shape[0], src.shape[1]), 1)
            mem = self._backbone(params, src, ctx._replace(positions=src_pos),
                                 seg_filter={"encoder"})
            ctx = ctx._replace(memory=mem)
            h = self._backbone(params, h, ctx, seg_filter={"decoder"})
        else:
            h = self._backbone(params, h, ctx)

        logits = self._logits(params, h)
        targets = jnp.roll(tokens, -1, axis=1)
        mask = batch["loss_mask"].at[:, -1].set(0.0)
        loss = softmax_cross_entropy(logits, targets, mask, cfg.vocab)

        if cfg.mtp_depth:
            # DeepSeek-V3 multi-token prediction: predict t+2 from (h_t, e_{t+1})
            mp = params["mtp"]
            nxt = embed_lookup(params["embed"], targets)
            # concat_rows: h is (dp, model, -) residual-sharded; sharded
            # concatenate miscompiles on jax 0.4.37 multi-axis meshes
            h2 = jnp.einsum("bsd,de->bse",
                            concat_rows([h, nxt], axis=-1,
                                        labels=("dp", "model", None)),
                            mp["proj"])
            h2 = rms_norm(h2, mp["ln"], cfg.norm_eps)
            h2 = (B.mla_apply if cfg.mla else B.attn_apply)(mp["attn"], h2, ctx, cfg)
            h2 = B.mlp_apply(mp["mlp"], h2, cfg)
            logits2 = self._logits(params, h2)
            t2 = jnp.roll(tokens, -2, axis=1)
            mask2 = mask.at[:, -2:].set(0.0)
            loss = loss + 0.3 * softmax_cross_entropy(logits2, t2, mask2, cfg.vocab)
        return loss

    # --------------------------------------------------------- serve: caches
    def cache_spec(self, batch: int, max_seq: int) -> dict:
        cfg = self.cfg
        out: dict[str, Any] = {}
        for seg in self.segments:
            if seg.kind in ("dense", "moe_dense", "moe"):
                per = (B.mla_cache_spec(cfg, batch, max_seq) if cfg.mla
                       else B.attn_cache_spec(cfg, batch, max_seq))
                out[seg.name] = _stack(per, seg.count)
            elif seg.kind == "rwkv":
                out[seg.name] = _stack(S.rwkv6_cache_spec(cfg, batch), seg.count)
            elif seg.kind == "mamba":
                out[seg.name] = _stack(S.mamba2_cache_spec(cfg, batch), seg.count)
            elif seg.kind == "mamba_group":
                out[seg.name] = {
                    "mamba": _stack(_stack(S.mamba2_cache_spec(cfg, batch),
                                           seg.inner), seg.count),
                    "attn": _stack(B.attn_cache_spec(cfg, batch, max_seq),
                                   seg.count)}
            elif seg.kind == "vlm_group":
                out[seg.name] = {
                    "self": _stack(_stack(
                        B.attn_cache_spec(cfg, batch, max_seq), seg.inner),
                        seg.count),
                    "cross": _stack(B.attn_cache_spec(cfg, batch,
                                                      cfg.frontend_tokens),
                                    seg.count)}
            elif seg.kind == "dec":
                out[seg.name] = {
                    "self": _stack(B.attn_cache_spec(cfg, batch, max_seq),
                                   seg.count),
                    "cross": _stack(B.attn_cache_spec(
                        cfg, batch, self._src_len(max_seq)), seg.count)}
            elif seg.kind == "enc":
                pass  # encoder output is carried in ctx.memory, not a cache
        return out

    @staticmethod
    def _src_len(max_seq: int) -> int:
        return max_seq

    def abstract_cache(self, batch: int, max_seq: int) -> dict:
        return abstract(self.cache_spec(batch, max_seq))

    # ---------------------------------------------------------- serve: decode
    def decode_step(self, params: dict, caches: dict, token: jax.Array,
                    length: jax.Array, memory: jax.Array | None = None):
        """One token for the whole batch. token (B,1) -> logits (B, vpad)."""
        cfg = self.cfg
        h = jnp.take(params["embed"], token, axis=0)
        ctx = Ctx(positions=None, length=length, memory=memory)
        new_caches: dict[str, Any] = {}
        for seg in self.segments:
            if seg.count == 0 or seg.kind == "enc":
                continue
            h, new_caches[seg.name] = self._decode_seg(
                seg, params, h, caches[seg.name], ctx)
        logits = self._logits(params, h)[:, 0]
        return logits, new_caches

    def _decode_seg(self, seg: Segment, params: dict, h: jax.Array,
                    cache, ctx: Ctx):
        cfg = self.cfg

        def run(body):
            if not self.unroll:
                h2, ys = jax.lax.scan(lambda c, xs: body(c, *xs), h,
                                      (params[seg.name], cache))
                return h2, ys
            hh, ys = h, []
            for i in range(seg.count):
                lp = jax.tree.map(lambda a: a[i], params[seg.name])
                lc = jax.tree.map(lambda a: a[i], cache)
                hh, y = body(hh, lp, lc)
                ys.append(y)
            # lint: ok(R001) unroll=True is roofline-only and runs off-mesh (replicated)
            ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
            return hh, ys

        if seg.kind in ("dense", "moe_dense", "moe"):
            attn_dec = B.mla_decode if cfg.mla else B.attn_decode

            def body(hh, lp, lc):
                hh, nc = attn_dec(lp["attn"], hh, lc, ctx, cfg)
                if seg.kind == "moe":
                    hh = B.moe_apply(lp["moe"], hh, cfg)
                else:
                    hh = B.mlp_apply(lp["mlp"], hh, cfg)
                return hh, nc
            return run(body)

        if seg.kind == "rwkv":
            def body(hh, lp, lc):
                hh, nc = S.rwkv6_decode(lp, hh, lc, cfg)
                return hh, nc
            return run(body)

        if seg.kind == "mamba":
            def body(hh, lp, lc):
                hh, nc = S.mamba2_decode(lp, hh, lc, cfg)
                return hh, nc
            return run(body)

        if seg.kind == "mamba_group":
            sp = params["shared_attn"]

            def body(hh, lp, lc):
                new_m = []
                for i in range(seg.inner):
                    mp = jax.tree.map(lambda a: a[i], lp["mamba"])
                    mc = jax.tree.map(lambda a: a[i], lc["mamba"])
                    hh, nm = S.mamba2_decode(mp, hh, mc, cfg)
                    new_m.append(nm)
                # lint: ok(R001) unroll=True is roofline-only and runs off-mesh (replicated)
                new_m = jax.tree.map(lambda *a: jnp.stack(a), *new_m)
                hh, na = B.attn_decode(sp["attn"], hh, lc["attn"], ctx, cfg)
                hh = B.mlp_apply(sp["mlp"], hh, cfg)
                return hh, {"mamba": new_m, "attn": na}
            return run(body)

        if seg.kind == "vlm_group":
            def body(hh, lp, lc):
                new_s = []
                for i in range(seg.inner):
                    sl = jax.tree.map(lambda a: a[i], lp["self"])
                    sc = jax.tree.map(lambda a: a[i], lc["self"])
                    hh, ns = B.attn_decode(sl["attn"], hh, sc, ctx, cfg)
                    hh = B.mlp_apply(sl["mlp"], hh, cfg)
                    new_s.append(ns)
                # lint: ok(R001) unroll=True is roofline-only and runs off-mesh (replicated)
                new_s = jax.tree.map(lambda *a: jnp.stack(a), *new_s)
                hh, nx = self._cross_decode(lp["cross"]["attn"], hh,
                                            lc["cross"], ctx)
                hh = B.mlp_apply(lp["cross"]["mlp"], hh, cfg)
                return hh, {"self": new_s, "cross": nx}
            return run(body)

        if seg.kind == "dec":
            def body(hh, lp, lc):
                hh, ns = B.attn_decode(lp["attn"], hh, lc["self"], ctx, cfg)
                hh, nx = self._cross_decode(lp["cross"], hh, lc["cross"], ctx)
                hh = B.mlp_apply(lp["mlp"], hh, cfg)
                return hh, {"self": ns, "cross": nx}
            return run(body)

        raise ValueError(seg.kind)

    def _cross_decode(self, p: dict, h: jax.Array, cache: dict, ctx: Ctx):
        """Cross-attention against a prefilled (encoder/image) KV cache."""
        cfg = self.cfg
        from repro.models.layers import decode_attention
        x = rms_norm(h, p["ln"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhq->bshq", x, p["wq"])
        o = decode_attention(q, cache["k"], cache["v"],
                             jnp.int32(cache["k"].shape[1]))
        g = jnp.tanh(p["gate"].astype(jnp.float32)).astype(h.dtype) \
            if "gate" in p else 1.0
        return h + g * jnp.einsum("bshq,hqd->bsd", o, p["wo"]).astype(h.dtype), cache

    # --------------------------------------------------------- serve: prefill
    def prefill(self, params: dict, tokens: jax.Array, max_seq: int,
                memory: jax.Array | None = None):
        """Process a full prompt, returning (last-position logits, caches)."""
        cfg = self.cfg
        bsz, seq = tokens.shape
        pos = jax.lax.broadcasted_iota(jnp.int32, (bsz, seq), 1)
        ctx = Ctx(positions=pos, length=jnp.int32(0), memory=memory)
        h = shard_res(embed_lookup(params["embed"], tokens))
        caches: dict[str, Any] = {}

        if cfg.family == "encdec":
            src_pos = jax.lax.broadcasted_iota(
                jnp.int32, (memory.shape[0], memory.shape[1]), 1)
            mem = self._backbone(params, memory, ctx._replace(positions=src_pos),
                                 seg_filter={"encoder"})
            ctx = ctx._replace(memory=mem)

        for seg in self.segments:
            if seg.count == 0 or seg.kind == "enc":
                continue
            h, caches[seg.name] = self._prefill_seg(seg, params, h, ctx, max_seq)
        logits = self._logits(params, h[:, -1:])[:, 0]
        return logits, caches

    def _prefill_seg(self, seg: Segment, params: dict, h: jax.Array, ctx: Ctx,
                     max_seq: int):
        cfg = self.cfg

        def run(body):
            if not self.unroll:
                return jax.lax.scan(self._remat(body), h, params[seg.name])
            hh, ys = h, []
            for i in range(seg.count):
                lp = jax.tree.map(lambda a: a[i], params[seg.name])
                hh, y = body(hh, lp)
                ys.append(y)
            # lint: ok(R001) unroll=True is roofline-only and runs off-mesh (replicated)
            ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
            return hh, ys

        if seg.kind in ("dense", "moe_dense", "moe"):
            pre = B.mla_prefill_cache if cfg.mla else B.attn_prefill_cache

            def body(hh, lp):
                hh, c = pre(lp["attn"], hh, ctx, cfg, max_seq)
                if seg.kind == "moe":
                    hh = B.moe_apply(lp["moe"], hh, cfg)
                else:
                    hh = B.mlp_apply(lp["mlp"], hh, cfg)
                return hh, c
            return run(body)

        if seg.kind == "rwkv":
            def body(hh, lp):
                hh, st, l1, l2 = S.rwkv6_apply(lp, hh, cfg)
                return hh, {"state": st, "last1": l1, "last2": l2}
            return run(body)

        if seg.kind == "mamba":
            def body(hh, lp):
                return S.mamba2_apply(lp, hh, cfg, return_cache=True)
            return run(body)

        if seg.kind == "mamba_group":
            sp = params["shared_attn"]

            def body(hh, lp):
                caches_m = []
                for i in range(seg.inner):
                    mp = jax.tree.map(lambda a: a[i], lp["mamba"])
                    hh, cm_i = S.mamba2_apply(mp, hh, cfg, return_cache=True)
                    caches_m.append(cm_i)
                # lint: ok(R001) unroll=True is roofline-only and runs off-mesh (replicated)
                cm = jax.tree.map(lambda *a: jnp.stack(a), *caches_m)
                hh, ca = B.attn_prefill_cache(sp["attn"], hh, ctx, cfg, max_seq)
                hh = B.mlp_apply(sp["mlp"], hh, cfg)
                return hh, {"mamba": cm, "attn": ca}
            return run(body)

        if seg.kind == "vlm_group":
            def body(hh, lp):
                cs = []
                for i in range(seg.inner):
                    sl = jax.tree.map(lambda a: a[i], lp["self"])
                    hh, c = B.attn_prefill_cache(sl["attn"], hh, ctx, cfg, max_seq)
                    hh = B.mlp_apply(sl["mlp"], hh, cfg)
                    cs.append(c)
                # lint: ok(R001) unroll=True is roofline-only and runs off-mesh (replicated)
                cs = jax.tree.map(lambda *a: jnp.stack(a), *cs)
                hh, cx = self._cross_prefill(lp["cross"]["attn"], hh, ctx)
                hh = B.mlp_apply(lp["cross"]["mlp"], hh, cfg)
                return hh, {"self": cs, "cross": cx}
            return run(body)

        if seg.kind == "dec":
            def body(hh, lp):
                hh, cself = B.attn_prefill_cache(lp["attn"], hh, ctx, cfg, max_seq)
                hh, cx = self._cross_prefill(lp["cross"], hh, ctx)
                hh = B.mlp_apply(lp["mlp"], hh, cfg)
                return hh, {"self": cself, "cross": cx}
            return run(body)

        raise ValueError(seg.kind)

    def _cross_prefill(self, p: dict, h: jax.Array, ctx: Ctx):
        cfg = self.cfg
        mem = ctx.memory
        x = rms_norm(h, p["ln"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhq->bshq", x, p["wq"])
        k = jnp.einsum("bsd,dhq->bshq", mem, p["wk"])
        v = jnp.einsum("bsd,dhq->bshq", mem, p["wv"])
        from repro.models.layers import attention
        o = attention(q, k, v, causal=False)
        g = jnp.tanh(p["gate"].astype(jnp.float32)).astype(h.dtype) \
            if "gate" in p else 1.0
        out = h + g * jnp.einsum("bshq,hqd->bsd", o, p["wo"]).astype(h.dtype)
        return out, {"k": k.astype(BF16), "v": v.astype(BF16)}
