"""GNNs in the paper's aggregate/update message-passing form (Eq. 2).

Every architecture is expressed through three pure functions so that the LMC
machinery (core/) can drive forward compensation and the *explicit*
message-passing backward pass (Eq. 11-13) with per-layer ``jax.vjp``:

  embed_apply(params.embed, x)                  -> H^0            (no aggregation)
  layer_apply(params.layers[l], h_in, aux)      -> h_out          (one MP layer)
  head_apply(params.head, h)                    -> logits         (output layer w)

``aux`` carries the edge list (local COO: src, dst, weight), raw features and
H^0 (for GCNII's initial-residual term). Aggregation is a weighted
segment-sum — the jnp oracle of the Pallas SpMM kernel (kernels/ref.py). Two
ways to put the kernel on the hot path: bind ``aggregate=ell_aggregate_fn(g)``
at construction (full-graph use), or populate ``aux.ell`` with the batch's
``ELLGraph`` — when present, layers aggregate through the differentiable
``kernels.bucketed_spmm`` (its custom VJP runs the transposed-adjacency SpMM,
so the LMC per-layer ``jax.vjp`` calls stay on the kernel; DESIGN.md §3).
``make_train_step(..., backend="ell")`` selects the latter, and
``backend="ti"`` reuses the identical ELL aggregation path — the backends
differ only in how core/lmc.py compensates halo rows afterwards (store gather
vs. message-invariant rescale), which this module never sees.

Supported: GCN (Kipf & Welling 2017), GCNII (Chen et al. 2020), GraphSAGE
(Hamilton et al. 2017), GIN (Xu et al. 2019) — the families used by the paper
and its baselines.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np


class EdgeList(NamedTuple):
    src: jax.Array   # (E,) int32 local source rows
    dst: jax.Array   # (E,) int32 local destination rows
    w: jax.Array     # (E,) float32 normalized weights (0 = padding)


class LayerAux(NamedTuple):
    edges: EdgeList
    x: jax.Array          # (N, dx) raw features of the local rows
    h0: jax.Array         # (N, d) initial embedding (GCNII); zeros otherwise
    self_w: jax.Array     # (N,) self-loop weight 1/(deg+1) for GCN-normalized agg
    ell: Optional[Any] = None  # kernels.ELLGraph: aggregate via bucketed_spmm
    stream: Optional[bool] = None  # HBM→VMEM DMA gather knob (None: autodetect)


def segment_spmm(edges: EdgeList, h: jax.Array, num_rows: int) -> jax.Array:
    """out[i] = Σ_{(j->i)} w_ji * h[j] — the reference aggregation."""
    msgs = h[edges.src] * edges.w[:, None]
    return jax.ops.segment_sum(msgs, edges.dst, num_segments=num_rows)


AggregateFn = Callable[[EdgeList, jax.Array, int], jax.Array]


@dataclasses.dataclass(frozen=True)
class GNN:
    """A GNN family bound to its hyperparameters; produces pure fns + params."""

    arch: str                  # gcn | gcnii | sage | gin
    feature_dim: int
    hidden_dim: int
    num_classes: int
    num_layers: int
    alpha: float = 0.1         # GCNII initial-residual strength
    lam: float = 0.5           # GCNII identity-map strength (beta_l = log(lam/l+1))
    aggregate: AggregateFn = staticmethod(segment_spmm)

    # ------------------------------------------------------------------ params
    def init_params(self, rng: jax.Array) -> dict:
        dx, d, c, L = self.feature_dim, self.hidden_dim, self.num_classes, self.num_layers
        ks = jax.random.split(rng, L + 2)

        def glorot(key, shape):
            lim = float(np.sqrt(6.0 / (shape[-2] + shape[-1])))
            return jax.random.uniform(key, shape, jnp.float32, -lim, lim)

        if self.arch == "gcn":
            dims = [dx] + [d] * L
            layers = {
                "w": [glorot(ks[l], (dims[l], dims[l + 1])) for l in range(L)],
                "b": [jnp.zeros((dims[l + 1],)) for l in range(L)],
            }
            embed = {}
        elif self.arch == "gcnii":
            layers = {"w": [glorot(ks[l], (d, d)) for l in range(L)]}
            embed = {"w": glorot(ks[L], (dx, d)), "b": jnp.zeros((d,))}
        elif self.arch == "sage":
            dims = [dx] + [d] * L
            layers = {
                "w_self": [glorot(ks[l], (dims[l], dims[l + 1])) for l in range(L)],
                "w_nbr": [glorot(jax.random.fold_in(ks[l], 1), (dims[l], dims[l + 1]))
                          for l in range(L)],
                "b": [jnp.zeros((dims[l + 1],)) for l in range(L)],
            }
            embed = {}
        elif self.arch == "gin":
            dims = [dx] + [d] * L
            layers = {
                "w1": [glorot(ks[l], (dims[l], dims[l + 1])) for l in range(L)],
                "b1": [jnp.zeros((dims[l + 1],)) for l in range(L)],
                "w2": [glorot(jax.random.fold_in(ks[l], 1), (dims[l + 1], dims[l + 1]))
                       for l in range(L)],
                "b2": [jnp.zeros((dims[l + 1],)) for l in range(L)],
                "eps": [jnp.zeros(()) for _ in range(L)],
            }
            embed = {}
        else:
            raise ValueError(self.arch)

        # stack per-layer params only when shapes agree (gcnii); else keep lists
        head = {"w": glorot(ks[L + 1], (d, c)), "b": jnp.zeros((c,))}
        return {"embed": embed, "layers": layers, "head": head}

    def layer_params(self, params: dict, l: int):
        return jax.tree.map(lambda leaf: leaf[l], params["layers"],
                            is_leaf=lambda leaf: isinstance(leaf, list))

    # ------------------------------------------------------------------- fns
    def embed_apply(self, embed: dict, x: jax.Array) -> jax.Array:
        if self.arch == "gcnii":
            return jax.nn.relu(x @ embed["w"] + embed["b"])
        return x  # H^0 = X for gcn/sage/gin

    def _aggregate(self, aux: LayerAux, h: jax.Array, n: int) -> jax.Array:
        """Route aggregation: Pallas ELL kernel when the batch carries an
        ELLGraph (train-step ``backend="ell"``), else the bound AggregateFn."""
        if aux.ell is not None:
            from repro.kernels import bucketed_spmm
            return bucketed_spmm(aux.ell, h, stream=aux.stream)
        return self.aggregate(aux.edges, h, n)

    def layer_apply(self, lp: dict, l: int, h_in: jax.Array, aux: LayerAux) -> jax.Array:
        """One message-passing layer over the local row set (batch + halo)."""
        n = h_in.shape[0]
        if self.arch == "gcn":
            agg = self._aggregate(aux, h_in, n) + aux.self_w[:, None] * h_in
            return jax.nn.relu(agg @ lp["w"] + lp["b"])
        if self.arch == "gcnii":
            agg = self._aggregate(aux, h_in, n) + aux.self_w[:, None] * h_in
            beta_l = float(np.log(self.lam / (l + 1) + 1.0))
            sup = (1 - self.alpha) * agg + self.alpha * aux.h0
            out = (1 - beta_l) * sup + beta_l * (sup @ lp["w"])
            return jax.nn.relu(out)
        if self.arch == "sage":
            deg = jax.ops.segment_sum(aux.edges.w, aux.edges.dst, num_segments=n)
            agg = self._aggregate(aux, h_in, n) / jnp.maximum(deg, 1e-9)[:, None]
            return jax.nn.relu(h_in @ lp["w_self"] + agg @ lp["w_nbr"] + lp["b"])
        if self.arch == "gin":
            agg = self._aggregate(aux, h_in, n) + (1.0 + lp["eps"]) * h_in
            hid = jax.nn.relu(agg @ lp["w1"] + lp["b1"])
            return jax.nn.relu(hid @ lp["w2"] + lp["b2"])
        raise ValueError(self.arch)

    def head_apply(self, head: dict, h: jax.Array) -> jax.Array:
        return h @ head["w"] + head["b"]

    def layer_out_dim(self, l: int) -> int:
        return self.hidden_dim

    # ----------------------------------------------------- full-graph forward
    def full_forward(self, params: dict, x: jax.Array, edges: EdgeList,
                     self_w: jax.Array) -> jax.Array:
        """Exact full-batch forward -> logits (evaluation / full-batch GD)."""
        h0 = self.embed_apply(params["embed"], x)
        aux = LayerAux(edges=edges, x=x, h0=h0, self_w=self_w)
        h = h0
        for l in range(self.num_layers):
            h = self.layer_apply(self.layer_params(params, l), l, h, aux)
        return self.head_apply(params["head"], h)


def make_gnn(arch: str, feature_dim: int, hidden_dim: int, num_classes: int,
             num_layers: int, aggregate: Optional[AggregateFn] = None,
             **kw: Any) -> GNN:
    agg = aggregate if aggregate is not None else segment_spmm
    return GNN(arch=arch, feature_dim=feature_dim, hidden_dim=hidden_dim,
               num_classes=num_classes, num_layers=num_layers, aggregate=agg, **kw)


def full_edge_list(indptr: np.ndarray, indices: np.ndarray,
                   weights: np.ndarray) -> EdgeList:
    src = np.repeat(np.arange(indptr.shape[0] - 1), np.diff(indptr)).astype(np.int32)
    return EdgeList(src=jnp.asarray(indices.astype(np.int32)),
                    dst=jnp.asarray(src),
                    w=jnp.asarray(weights))
