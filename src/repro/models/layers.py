"""LM layer primitives: norms, RoPE, chunked (flash-style) attention, GQA,
decode attention over (possibly sequence-sharded) KV caches.

TPU-native conventions (DESIGN.md §7):
  * no S×S mask constants — iota comparisons only;
  * chunked attention bounds activation memory without a custom kernel;
  * attention logits are explicitly sharded: by heads when the head count
    divides the model axis, else by query position (sequence parallel) — this
    keeps the flash accumulators O(1/n_model) per device for every assigned
    arch, including 40/56-head models that a 16-way TP axis cannot split.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.sharding import concat_rows, model_axis_size, shard_act

BF16 = jnp.bfloat16
NEG_INF = -1e30


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    n = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (n * scale.astype(jnp.float32)).astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., S, H, dh); positions: (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    # concat_rows (not jnp.concatenate): q/k arrive (dp, -, model, -)
    # head-sharded and jax 0.4.37 miscompiles sharded concatenate on
    # multi-axis meshes — see repro.dist.sharding.concat_rows
    labels = ("dp",) + (None,) * (x.ndim - 3) + ("model", None)
    out = concat_rows([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1,
                      labels=labels)
    return out.astype(x.dtype)


def _attn_labels(h: int, sq: int):
    """Pick the shardable dim for (B, H, Sq, T) attention intermediates."""
    msz = model_axis_size()
    if msz > 1 and h % msz == 0:
        return ("dp", "model", None, None)
    if msz > 1 and sq % msz == 0:
        return ("dp", None, "model", None)
    return ("dp", None, None, None)


def _repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    if n_rep == 1:
        return k
    return jnp.repeat(k, n_rep, axis=2)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool,
              q_offset: int | jax.Array = 0, kv_chunk: int = 0,
              softmax_scale: Optional[float] = None) -> jax.Array:
    """GQA attention. q (B,Sq,H,dh); k,v (B,T,KV,dhk/dhv). Returns (B,Sq,H,dhv).

    kv_chunk > 0 runs a flash-style streaming softmax over KV chunks (lax.scan)
    so no (Sq, T) tensor larger than (Sq, kv_chunk) is materialized.
    """
    b, sq, h, dh = q.shape
    t, n_kv = k.shape[1], k.shape[2]
    dhv = v.shape[-1]
    scale = softmax_scale if softmax_scale is not None else 1.0 / np.sqrt(dh)
    k = _repeat_kv(k, h // n_kv)
    v = _repeat_kv(v, h // n_kv)
    qs = (q * scale).astype(q.dtype)
    q_pos = q_offset + jax.lax.broadcasted_iota(jnp.int32, (sq,), 0)
    lbl = _attn_labels(h, sq)

    if not kv_chunk or kv_chunk >= t:
        logits = shard_act(jnp.einsum("bshd,bthd->bhst", qs, k,
                                      preferred_element_type=jnp.float32), *lbl)
        if causal:
            k_pos = jax.lax.broadcasted_iota(jnp.int32, (t,), 0)
            mask = q_pos[:, None] >= k_pos[None, :]
            logits = jnp.where(mask[None, None], logits, NEG_INF)
        p = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhst,bthd->bshd", p.astype(v.dtype), v)
        return out

    nchunks = t // kv_chunk
    k_c = k.reshape(b, nchunks, kv_chunk, h, dh)
    v_c = v.reshape(b, nchunks, kv_chunk, h, dhv)

    def body(carry, inputs):
        m, l, acc = carry
        kc, vc, ci = inputs
        logits = shard_act(jnp.einsum("bshd,bthd->bhst", qs, kc,
                                      preferred_element_type=jnp.float32), *lbl)
        if causal:
            k_pos = ci * kv_chunk + jax.lax.broadcasted_iota(
                jnp.int32, (kv_chunk,), 0)
            mask = q_pos[:, None] >= k_pos[None, :]
            logits = jnp.where(mask[None, None], logits, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhst,bthd->bhsd", p.astype(vc.dtype), vc).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = shard_act(jnp.full((b, h, sq), NEG_INF, jnp.float32), *lbl[:3])
    l0 = shard_act(jnp.zeros((b, h, sq), jnp.float32), *lbl[:3])
    a0 = shard_act(jnp.zeros((b, h, sq, dhv), jnp.float32), *lbl)
    # remat the chunk body: backward recomputes per-chunk logits instead of
    # storing the full (Sq, T) matrix stacked over chunks (flash-attn bwd).
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(body), (m0, l0, a0),
        (k_c.swapaxes(0, 1), v_c.swapaxes(0, 1),
         jnp.arange(nchunks, dtype=jnp.int32)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.swapaxes(1, 2).astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     length: jax.Array, *,
                     softmax_scale: Optional[float] = None) -> jax.Array:
    """Single-position attention over a KV cache.

    q (B,1,H,dh); caches (B,T,KV,dh*); ``length`` = number of valid positions.
    Works with the cache sequence axis sharded (sums/softmax over T become
    cross-shard collectives under GSPMD).
    """
    b, _, h, dh = q.shape
    t, n_kv = k_cache.shape[1], k_cache.shape[2]
    scale = softmax_scale if softmax_scale is not None else 1.0 / np.sqrt(dh)
    qg = q.reshape(b, n_kv, h // n_kv, dh) * scale                # (B,KV,G,dh)
    logits = jnp.einsum("bkgd,btkd->bkgt", qg, k_cache,
                        preferred_element_type=jnp.float32)
    pos = jax.lax.broadcasted_iota(jnp.int32, (t,), 0)
    logits = jnp.where((pos < length)[None, None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p.astype(v_cache.dtype), v_cache)
    return out.reshape(b, 1, h, v_cache.shape[-1])


@jax.custom_vjp
def embed_lookup(embed: jax.Array, tokens: jax.Array) -> jax.Array:
    """Embedding gather with a *sharded, bf16* scatter-add backward.

    The default `take` VJP scatter-adds into a full f32 (vocab, d) buffer that
    GSPMD replicates per device and all-reduces (3.76 GiB f32 per copy for the
    671B config). This custom VJP keeps the cotangent in the embedding dtype
    and pins the (vocab: model, d: data) sharding on the scatter."""
    return jnp.take(embed, tokens, axis=0)


def _embed_fwd(embed, tokens):
    # `embed` in the residuals is an alias of the parameter (no extra memory);
    # only its shape/dtype are used in the backward.
    return embed_lookup(embed, tokens), (tokens, embed)


def _embed_bwd(res, dh):
    tokens, embed = res
    flat_ids = tokens.reshape(-1)
    dh_flat = dh.reshape(-1, dh.shape[-1]).astype(embed.dtype)
    z = shard_act(jnp.zeros_like(embed), "model", "dp")
    demb = shard_act(z.at[flat_ids].add(dh_flat), "model", "dp")
    return demb, None


embed_lookup.defvjp(_embed_fwd, _embed_bwd)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    act = jax.nn.silu(g) * u
    if act.ndim == 3:
        act = shard_act(act, "dp", None, "model")
    return jnp.einsum("...f,fd->...d", act, w_down).astype(x.dtype)


def softmax_cross_entropy(logits: jax.Array, targets: jax.Array,
                          mask: jax.Array, vocab_valid: int) -> jax.Array:
    """Mean NLL over masked targets; padded vocab columns are excluded.

    Written gather-free (logsumexp + masked select) so a vocab-sharded logits
    tensor never has to be all-gathered.
    """
    logits = logits.astype(jnp.float32)
    col = jax.lax.broadcasted_iota(jnp.int32, (logits.shape[-1],), 0)
    ndim_pad = (None,) * (logits.ndim - 1)
    logits = jnp.where(col[ndim_pad] < vocab_valid, logits, NEG_INF)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    lab = jnp.sum(jnp.where(col[ndim_pad] == targets[..., None], logits, 0.0),
                  axis=-1)
    ll = lab - lse
    return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
