"""SSM blocks: Mamba2 (SSD, chunked matmul form) and RWKV6 (Finch).

TPU adaptation (DESIGN.md §7.6): both recurrences are organized so the
FLOP-dominant work is MXU matmuls outside any `lax.scan`:
  * Mamba2 uses the SSD block decomposition — intra-chunk "attention-like"
    matmuls + an O(cheap) inter-chunk state scan;
  * RWKV6 runs its per-channel-decay recurrence as a scan over chunk-local
    steps vectorized across all chunks; the state ops are <1% of the layer's
    projection FLOPs (measured in EXPERIMENTS.md §Roofline notes).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist.sharding import concat_rows, shard_act, shard_res
from repro.models.layers import rms_norm, BF16
from repro.models.spec import PSpec


# ==================================================================== Mamba2
def mamba2_spec(cfg: ArchConfig) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    n_heads = d_in // s.head_dim
    conv_dim = d_in + 2 * s.n_groups * s.d_state
    return {
        "ln": PSpec((d,), ("embed",), init="ones"),
        # order: [z (gate), x, B, C, dt]
        "w_in": PSpec((d, 2 * d_in + 2 * s.n_groups * s.d_state + n_heads),
                      ("embed", "mlp")),
        "conv_w": PSpec((s.d_conv, conv_dim), ("dconv", "mlp")),
        "conv_b": PSpec((conv_dim,), ("mlp",), init="zeros"),
        "a_log": PSpec((n_heads,), (None,), init="zeros", dtype=jnp.float32),
        "dt_bias": PSpec((n_heads,), (None,), init="zeros", dtype=jnp.float32),
        "d_skip": PSpec((n_heads,), (None,), init="ones", dtype=jnp.float32),
        "out_ln": PSpec((d_in,), ("mlp",), init="ones"),
        "w_out": PSpec((d_in, d), ("mlp", "embed")),
    }


def _mamba_proj(p: dict, x: jax.Array, cfg: ArchConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    gn = s.n_groups * s.d_state
    n_heads = d_in // s.head_dim
    zxbcdt = shard_act(jnp.einsum("bsd,de->bse", x, p["w_in"]),
                       "dp", None, "model")
    z = zxbcdt[..., :d_in]
    xin = zxbcdt[..., d_in:2 * d_in]
    Bc = zxbcdt[..., 2 * d_in:2 * d_in + gn]
    Cc = zxbcdt[..., 2 * d_in + gn:2 * d_in + 2 * gn]
    dt = zxbcdt[..., 2 * d_in + 2 * gn:]
    assert dt.shape[-1] == n_heads
    # concat_rows (not jnp.concatenate): xin/Bc/Cc are slices of the
    # (dp, -, model)-sharded projection, re-joined along the model-sharded
    # feature axis — exactly the sharded concat jax 0.4.37 miscompiles
    return z, concat_rows([xin, Bc, Cc], axis=-1,
                          labels=("dp", None, "model")), dt


def _causal_conv(u: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d via shifted adds (kernel is tiny)."""
    k = w.shape[0]
    out = u * w[k - 1]
    for i in range(1, k):
        shifted = jnp.pad(u, ((0, 0), (i, 0), (0, 0)))[:, :u.shape[1]]
        out = out + shifted * w[k - 1 - i]
    return jax.nn.silu(out + b)


def mamba2_apply(p: dict, h: jax.Array, cfg: ArchConfig,
                 return_cache: bool = False):
    """Full-sequence SSD. h: (B, S, d). With ``return_cache`` also returns the
    post-sequence recurrent cache {conv, state} for decode continuation."""
    s = cfg.ssm
    B_, S, d = h.shape
    d_in = s.expand * d
    H = d_in // s.head_dim
    P, N, G = s.head_dim, s.d_state, s.n_groups
    cs = s.chunk

    x0 = rms_norm(h, p["ln"], cfg.norm_eps)
    z, conv_in, dt = _mamba_proj(p, x0, cfg)
    conv_out = _causal_conv(conv_in, p["conv_w"], p["conv_b"])

    S_real = S
    pad = (-S) % cs
    if pad:
        # dt is forced to 0 at padded steps => identity state transitions
        conv_out = jnp.pad(conv_out, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    xin = conv_out[..., :d_in]
    Bc = conv_out[..., d_in:d_in + G * N].reshape(B_, S, G, N)
    Cc = conv_out[..., d_in + G * N:].reshape(B_, S, G, N)

    a = -jnp.exp(p["a_log"])                                    # (H,)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    if pad:
        t_idx = jax.lax.broadcasted_iota(jnp.int32, (S,), 0)
        dt = dt * (t_idx < S_real)[None, :, None]
    dA = dt * a                                                  # (B,S,H) <=0
    nc = S // cs

    xh = xin.reshape(B_, nc, cs, H, P)
    Bh = Bc.reshape(B_, nc, cs, G, N)
    Ch = Cc.reshape(B_, nc, cs, G, N)
    dtc = dt.reshape(B_, nc, cs, H)
    dAc = dA.reshape(B_, nc, cs, H)
    cum = jnp.cumsum(dAc, axis=2)                                # (B,nc,cs,H)

    # --- intra-chunk (per-head decay between positions) -------------------
    rep = H // G
    att = jnp.einsum("bnigm,bnjgm->bngij", Ch, Bh,
                     preferred_element_type=jnp.float32)          # (B,nc,G,cs,cs)
    att = jnp.repeat(att, rep, axis=2)                            # (B,nc,H,cs,cs)
    decay = cum[:, :, :, None, :] - cum[:, :, None, :, :]         # i,j -> (B,nc,cs,cs,H)
    decay = jnp.transpose(decay, (0, 1, 4, 2, 3))                 # (B,nc,H,cs,cs)
    ii = jax.lax.broadcasted_iota(jnp.int32, (cs, cs), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (cs, cs), 1)
    causal = (ii >= jj)[None, None, None]
    att = jnp.where(causal, att * jnp.exp(decay), 0.0)
    att = att * jnp.transpose(dtc, (0, 1, 3, 2))[:, :, :, None, :]
    y_intra = jnp.einsum("bnhij,bnjhp->bnihp", att.astype(xh.dtype), xh)

    # --- chunk-local states + inter-chunk scan (cheap) ---------------------
    w_local = jnp.exp(cum[:, :, -1:, :] - cum) * dtc              # (B,nc,cs,H)
    state_loc = jnp.einsum("bnjgm,bnjh,bnjhp->bnhmp",
                           Bh.astype(jnp.float32), w_local,
                           xh.astype(jnp.float32))                # (B,nc,H,N,P)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                       # (B,nc,H)

    def scan_body(carry, inp):
        st_loc, dec = inp                                         # (B,H,N,P),(B,H)
        new = carry * dec[..., None, None] + st_loc
        return new, carry                                          # emit PREVIOUS

    init = jnp.zeros((B_, H, N, P), jnp.float32)
    final_state, prev_states = jax.lax.scan(
        scan_body, init,
        (state_loc.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    prev_states = prev_states.swapaxes(0, 1)                      # (B,nc,H,N,P)

    Ch_h = jnp.repeat(Ch, rep, axis=3).reshape(B_, nc, cs, H, N)
    y_inter = jnp.einsum("bnihm,bnhmp->bnihp",
                         (Ch_h * jnp.exp(cum)[..., None]).astype(jnp.float32),
                         prev_states)
    y = (y_intra.astype(jnp.float32) + y_inter
         + xh.astype(jnp.float32) * p["d_skip"][:, None])
    y = y.reshape(B_, S, d_in)[:, :S_real]
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rms_norm(y.astype(h.dtype), p["out_ln"], cfg.norm_eps)
    out = shard_res(h + jnp.einsum("bse,ed->bsd", y, p["w_out"]).astype(h.dtype))
    if return_cache:
        cache = {"conv": conv_in[:, S_real - (s.d_conv - 1):S_real].astype(jnp.float32),
                 "state": final_state}
        return out, cache
    return out


def mamba2_cache_spec(cfg: ArchConfig, batch: int) -> dict:
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_dim
    conv_dim = d_in + 2 * s.n_groups * s.d_state
    return {
        "conv": PSpec((batch, s.d_conv - 1, conv_dim),
                      ("batch", None, "mlp"), init="zeros", dtype=jnp.float32),
        "state": PSpec((batch, H, s.d_state, s.head_dim),
                       ("batch", "heads", None, None), init="zeros",
                       dtype=jnp.float32),
    }


def mamba2_decode(p: dict, h: jax.Array, cache: dict, cfg: ArchConfig):
    """Single-token recurrent step. h: (B, 1, d)."""
    s = cfg.ssm
    B_, _, d = h.shape
    d_in = s.expand * d
    H, P, N, G = d_in // s.head_dim, s.head_dim, s.d_state, s.n_groups
    x0 = rms_norm(h, p["ln"], cfg.norm_eps)
    z, conv_in, dt = _mamba_proj(p, x0, cfg)
    # concat_rows: the conv cache/step are (dp, -, model) sharded; sharded
    # concatenate miscompiles on jax 0.4.37 multi-axis meshes
    hist = concat_rows([cache["conv"], conv_in.astype(jnp.float32)],
                       axis=1, labels=("dp", None, "model"))  # (B,k,conv)
    conv_out = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", hist, p["conv_w"].astype(jnp.float32))
        + p["conv_b"].astype(jnp.float32))
    xin = conv_out[:, :d_in].reshape(B_, H, P)
    Bc = conv_out[:, d_in:d_in + G * N].reshape(B_, G, N)
    Cc = conv_out[:, d_in + G * N:].reshape(B_, G, N)
    rep = H // G
    Bh = jnp.repeat(Bc, rep, axis=1)                               # (B,H,N)
    Chh = jnp.repeat(Cc, rep, axis=1)
    a = -jnp.exp(p["a_log"])
    dts = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    dec = jnp.exp(dts * a)                                          # (B,H)
    new_state = (cache["state"] * dec[..., None, None]
                 + jnp.einsum("bhm,bh,bhp->bhmp", Bh, dts,
                              xin.astype(jnp.float32)))
    y = jnp.einsum("bhm,bhmp->bhp", Chh, new_state) \
        + xin.astype(jnp.float32) * p["d_skip"][:, None]
    y = y.reshape(B_, 1, d_in) * jax.nn.silu(z.astype(jnp.float32))
    y = rms_norm(y.astype(h.dtype), p["out_ln"], cfg.norm_eps)
    out = h + jnp.einsum("bse,ed->bsd", y, p["w_out"]).astype(h.dtype)
    return out, {"conv": hist[:, 1:], "state": new_state}


# ==================================================================== RWKV6
def rwkv6_spec(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    H, K = cfg.n_heads, cfg.dh
    lora = 64
    return {
        "ln1": PSpec((d,), ("embed",), init="ones"),
        "ln2": PSpec((d,), ("embed",), init="ones"),
        # time-mix (wkv6)
        "mu_x": PSpec((d,), ("embed",), init="zeros", dtype=jnp.float32),
        "mu_rkvwg": PSpec((5, d), (None, "embed"), init="zeros", dtype=jnp.float32),
        "ddl_w1": PSpec((d, 5 * 32), ("embed", None)),
        "ddl_w2": PSpec((5, 32, d), (None, None, "embed")),
        "w_r": PSpec((d, H, K), ("embed", "heads", "head_dim")),
        "w_k": PSpec((d, H, K), ("embed", "heads", "head_dim")),
        "w_v": PSpec((d, H, K), ("embed", "heads", "head_dim")),
        "w_g": PSpec((d, H, K), ("embed", "heads", "head_dim")),
        "decay_base": PSpec((H, K), ("heads", "head_dim"), init="zeros",
                            dtype=jnp.float32),
        "decay_w1": PSpec((d, lora), ("embed", None)),
        "decay_w2": PSpec((lora, H, K), (None, "heads", "head_dim")),
        "bonus_u": PSpec((H, K), ("heads", "head_dim"), init="zeros",
                         dtype=jnp.float32),
        "gn_scale": PSpec((H, K), ("heads", "head_dim"), init="ones"),
        "w_o": PSpec((H, K, d), ("heads", "head_dim", "embed")),
        # channel-mix
        "mu_ck": PSpec((d,), ("embed",), init="zeros", dtype=jnp.float32),
        "mu_cr": PSpec((d,), ("embed",), init="zeros", dtype=jnp.float32),
        "cm_k": PSpec((d, cfg.d_ff), ("embed", "mlp")),
        "cm_v": PSpec((cfg.d_ff, d), ("mlp", "embed")),
        "cm_r": PSpec((d, d), ("embed", "embed2")),
    }


def _shift(x: jax.Array, last: jax.Array | None = None) -> jax.Array:
    """x_{t-1} with optional carried last token (decode).

    concat_rows (not jnp.concatenate): x is residual-sharded (dp, model, -)
    and sharded concatenate miscompiles on jax 0.4.37 multi-axis meshes.
    """
    if last is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :x.shape[1]]
    return concat_rows([last[:, None], x[:, :-1]], axis=1,
                       labels=("dp", "model", None)) \
        if x.shape[1] > 1 else last[:, None]


def _ddlerp(p: dict, x: jax.Array, xprev: jax.Array):
    """RWKV6 data-dependent token-shift: 5 mixed streams (r,k,v,w,g)."""
    xx = (xprev - x).astype(jnp.float32)
    base = x + xx * p["mu_x"]
    hidden = jnp.tanh(jnp.einsum("bsd,de->bse", base.astype(BF16), p["ddl_w1"]))
    hidden = hidden.reshape(*hidden.shape[:2], 5, 32)
    dyn = jnp.einsum("bsfe,fed->fbsd", hidden, p["ddl_w2"]).astype(jnp.float32)
    mixes = p["mu_rkvwg"][:, None, None] + dyn                    # (5,B,S,d)
    return [(x + xx * m).astype(BF16) for m in mixes]


def _wkv_scan(r, k, v, w, u, state):
    """Sequential wkv recurrence, vectorized over (B, chunks, heads).

    r,k,v: (B,T,H,K[,V]); w: per-step decay in (0,1) (B,T,H,K);
    state: (B,H,K,V). Returns out (B,T,H,V), final state.
    """
    def body(st, inp):
        r_t, k_t, v_t, w_t = inp                                  # (B,H,K),(B,H,V)...
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
        out = jnp.einsum("bhk,bhkv->bhv", r_t, st + u[None, :, :, None] * kv)
        st = st * w_t[..., None] + kv
        return st, out

    rr = r.swapaxes(0, 1)
    kk = k.swapaxes(0, 1)
    vv = v.swapaxes(0, 1)
    ww = w.swapaxes(0, 1)
    state, outs = jax.lax.scan(body, state, (rr, kk, vv, ww))
    return outs.swapaxes(0, 1), state


def rwkv6_apply(p: dict, h: jax.Array, cfg: ArchConfig,
                state: jax.Array | None = None, shift_last1=None,
                shift_last2=None):
    """Full-sequence RWKV6 layer (time-mix + channel-mix)."""
    B_, S, d = h.shape
    H, K = cfg.n_heads, cfg.dh
    x = rms_norm(h, p["ln1"], cfg.norm_eps)
    xr, xk, xv, xw, xg = _ddlerp(p, x, _shift(x, shift_last1))
    r = shard_act(jnp.einsum("bsd,dhk->bshk", xr, p["w_r"]),
                  "dp", None, "model", None).astype(jnp.float32)
    k = shard_act(jnp.einsum("bsd,dhk->bshk", xk, p["w_k"]),
                  "dp", None, "model", None).astype(jnp.float32)
    v = shard_act(jnp.einsum("bsd,dhk->bshk", xv, p["w_v"]),
                  "dp", None, "model", None).astype(jnp.float32)
    g = jax.nn.silu(shard_act(jnp.einsum("bsd,dhk->bshk", xg, p["w_g"]),
                              "dp", None, "model", None))
    dec_dyn = jnp.einsum("bsd,dl->bsl", xw, p["decay_w1"])
    dec = p["decay_base"][None, None] + jnp.einsum(
        "bsl,lhk->bshk", jnp.tanh(dec_dyn), p["decay_w2"]).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(dec))                                    # (B,S,H,K) in (0,1)

    st0 = jnp.zeros((B_, H, K, K), jnp.float32) if state is None else state
    out, st = _wkv_scan(r, k, v, w, p["bonus_u"], st0)
    out = out.reshape(B_, S, H, K)
    # per-head group norm
    mu = out.mean(-1, keepdims=True)
    var = ((out - mu) ** 2).mean(-1, keepdims=True)
    out = (out - mu) * jax.lax.rsqrt(var + 64e-5) * p["gn_scale"].astype(jnp.float32)
    out = (out * g.astype(jnp.float32)).astype(h.dtype)
    h = h + jnp.einsum("bshk,hkd->bsd", out, p["w_o"]).astype(h.dtype)

    # channel mix
    x2 = rms_norm(h, p["ln2"], cfg.norm_eps)
    x2p = _shift(x2, shift_last2)
    xk2 = (x2 + (x2p - x2) * p["mu_ck"]).astype(BF16)
    xr2 = (x2 + (x2p - x2) * p["mu_cr"]).astype(BF16)
    kk = shard_act(jnp.einsum("bsd,df->bsf", xk2, p["cm_k"]),
                   "dp", None, "model")
    kk = jnp.square(jax.nn.relu(kk))
    cv = jnp.einsum("bsf,fd->bsd", kk, p["cm_v"])
    rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr2, p["cm_r"]))
    h = shard_res(h + (rr * cv).astype(h.dtype))
    return h, st, x[:, -1], x2[:, -1]


def rwkv6_cache_spec(cfg: ArchConfig, batch: int) -> dict:
    H, K = cfg.n_heads, cfg.dh
    d = cfg.d_model
    return {
        "state": PSpec((batch, H, K, K), ("batch", "heads", None, None),
                       init="zeros", dtype=jnp.float32),
        "last1": PSpec((batch, d), ("batch", None), init="zeros"),
        "last2": PSpec((batch, d), ("batch", None), init="zeros"),
    }


def rwkv6_decode(p: dict, h: jax.Array, cache: dict, cfg: ArchConfig):
    out, st, l1, l2 = rwkv6_apply(p, h, cfg, state=cache["state"],
                                  shift_last1=cache["last1"],
                                  shift_last2=cache["last2"])
    return out, {"state": st, "last1": l1, "last2": l2}
