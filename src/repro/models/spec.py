"""Parameter spec trees: shapes + logical axes, materializable or abstract.

Every LM block declares its parameters as a tree of :class:`PSpec` leaves
(shape + logical axis names + init style). The same tree then produces
  * real arrays            (``materialize`` — smoke tests, examples)
  * ShapeDtypeStructs      (``abstract`` — the dry-run, no allocation)
  * NamedShardings         (``shardings`` — via logical->mesh axis rules)
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class PSpec:
    shape: tuple
    logical: tuple            # logical axis name (or None) per dim
    init: str = "normal"      # normal | zeros | ones
    scale: float = 0.02
    dtype: jnp.dtype = jnp.bfloat16

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def _is_spec(x) -> bool:
    return isinstance(x, PSpec)


def materialize(tree, rng: jax.Array):
    leaves, treedef = jax.tree.flatten(tree, is_leaf=_is_spec)
    keys = jax.random.split(rng, len(leaves))
    out = []
    for k, s in zip(keys, leaves):
        if s.init == "zeros":
            out.append(jnp.zeros(s.shape, s.dtype))
        elif s.init == "ones":
            out.append(jnp.ones(s.shape, s.dtype))
        else:
            out.append((jax.random.normal(k, s.shape, jnp.float32)
                        * s.scale).astype(s.dtype))
    return jax.tree.unflatten(treedef, out)


def abstract(tree):
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), tree,
                        is_leaf=_is_spec)


# logical axis -> mesh axes. `fsdp` resolves to ("data",) or ("pod","data").
def default_rules(fsdp_axes=("data",)) -> dict:
    return {
        "embed": fsdp_axes,       # weight-sharding (ZeRO/FSDP) dimension
        "embed2": ("model",),
        "batch": ("pod", "data"),      # activations / caches
        "cache_seq": ("model",),       # sequence-sharded decode KV caches
        "vocab": ("model",),
        "heads": ("model",),
        "kv_heads": ("model",),   # dropped when not divisible
        "mlp": ("model",),
        "experts": ("model",),
        "moe_mlp": ("data",),
        "kv_lora": ("model",),
        "q_lora": None,
        "head_dim": None,
        "state": None,
        "conv": None,
        "layers": None,
        "dconv": None,
        None: None,
    }


def partition_spec(spec: PSpec, rules: dict, mesh: Mesh) -> P:
    axes_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    used: set = set()
    out = []
    for dim, logical in zip(spec.shape, spec.logical):
        ax = rules.get(logical)
        if ax is None:
            out.append(None)
            continue
        ax = tuple(a for a in (ax if isinstance(ax, tuple) else (ax,))
                   if a in axes_sizes and a not in used)
        total = int(np.prod([axes_sizes[a] for a in ax])) if ax else 1
        if not ax or dim % total != 0:
            out.append(None)
            continue
        used.update(ax)
        out.append(ax if len(ax) > 1 else ax[0])
    return P(*out)


def shardings(tree, rules: dict, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, partition_spec(s, rules, mesh)),
        tree, is_leaf=_is_spec)
