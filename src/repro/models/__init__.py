"""Model definitions: GNNs (the paper's subject) and the assigned LM zoo."""
from repro.models.gnn import GNN, make_gnn

__all__ = ["GNN", "make_gnn"]
