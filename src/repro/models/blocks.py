"""Per-family transformer blocks: param specs + apply fns (train & decode).

Each block family provides:
  <family>_spec(cfg)                      -> PSpec tree (one layer)
  <family>_apply(p, h, ctx)               -> h'      (full-sequence: train/prefill)
  <family>_decode(p, h, cache, ctx)       -> h', cache'
  <family>_cache_spec(cfg, B, S)          -> PSpec tree of the per-layer cache

Caches store the *sequence* axis with logical name "cache_seq" so the dry-run
shards it over the `model` axis (sequence-sharded decode attention — see
DESIGN.md §7.5); MLA caches stay compressed (rank 512+64).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.dist.sharding import concat_rows, dp_axis_size, shard_act, shard_res
from repro.models.layers import (attention, decode_attention, rms_norm, rope,
                                 swiglu, BF16)
from repro.models.spec import PSpec


class Ctx(NamedTuple):
    """Non-param inputs threaded through blocks."""
    positions: jax.Array            # (B, S) absolute positions
    length: jax.Array               # scalar: valid cache length (decode)
    memory: jax.Array | None = None  # encoder output / image embeddings


# =============================================================== dense GQA attn
def attn_spec(cfg: ArchConfig) -> dict:
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.dh
    spec = {
        "ln": PSpec((d,), ("embed",), init="ones"),
        "wq": PSpec((d, h, dh), ("embed", "heads", "head_dim")),
        "wk": PSpec((d, kv, dh), ("embed", "kv_heads", "head_dim")),
        "wv": PSpec((d, kv, dh), ("embed", "kv_heads", "head_dim")),
        "wo": PSpec((h, dh, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        spec["bq"] = PSpec((h, dh), ("heads", "head_dim"), init="zeros")
        spec["bk"] = PSpec((kv, dh), ("kv_heads", "head_dim"), init="zeros")
        spec["bv"] = PSpec((kv, dh), ("kv_heads", "head_dim"), init="zeros")
    return spec


def _qkv(p: dict, x: jax.Array, cfg: ArchConfig):
    q = jnp.einsum("bsd,dhq->bshq", x, p["wq"])
    k = jnp.einsum("bsd,dhq->bshq", x, p["wk"])
    v = jnp.einsum("bsd,dhq->bshq", x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = shard_act(q, "dp", None, "model", None)
    k = shard_act(k, "dp", None, "model", None)
    v = shard_act(v, "dp", None, "model", None)
    return q, k, v


def attn_apply(p: dict, h: jax.Array, ctx: Ctx, cfg: ArchConfig,
               *, causal: bool = True) -> jax.Array:
    x = rms_norm(h, p["ln"], cfg.norm_eps)
    q, k, v = _qkv(p, x, cfg)
    q = rope(q, ctx.positions, cfg.rope_theta)
    k = rope(k, ctx.positions, cfg.rope_theta)
    chunk = cfg.attn_chunk if h.shape[1] > 2 * cfg.attn_chunk else 0
    o = attention(q, k, v, causal=causal, kv_chunk=chunk)
    o = shard_act(o, "dp", None, "model", None)
    out = h + jnp.einsum("bshq,hqd->bsd", o, p["wo"]).astype(h.dtype)
    return shard_res(out)


def attn_cache_spec(cfg: ArchConfig, batch: int, max_seq: int) -> dict:
    kv, dh = cfg.n_kv_heads, cfg.dh
    sh = (batch, max_seq, kv, dh)
    lg = ("batch", "cache_seq", "kv_heads", "head_dim")
    return {"k": PSpec(sh, lg, init="zeros"), "v": PSpec(sh, lg, init="zeros")}


def attn_prefill_cache(p: dict, h: jax.Array, ctx: Ctx, cfg: ArchConfig,
                       max_seq: int):
    """Full-seq forward that also returns the populated KV cache."""
    x = rms_norm(h, p["ln"], cfg.norm_eps)
    q, k, v = _qkv(p, x, cfg)
    q = rope(q, ctx.positions, cfg.rope_theta)
    k = rope(k, ctx.positions, cfg.rope_theta)
    chunk = cfg.attn_chunk if h.shape[1] > 2 * cfg.attn_chunk else 0
    o = attention(q, k, v, causal=True, kv_chunk=chunk)
    o = shard_act(o, "dp", None, "model", None)
    out = h + jnp.einsum("bshq,hqd->bsd", o, p["wo"]).astype(h.dtype)
    out = shard_res(out)
    pad = max_seq - k.shape[1]
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return out, {"k": k.astype(BF16), "v": v.astype(BF16)}


def attn_decode(p: dict, h: jax.Array, cache: dict, ctx: Ctx, cfg: ArchConfig):
    x = rms_norm(h, p["ln"], cfg.norm_eps)
    q, k, v = _qkv(p, x, cfg)
    pos = ctx.length[None, None] * jnp.ones(h.shape[:2], jnp.int32)
    q = rope(q, pos, cfg.rope_theta)
    k = rope(k, pos, cfg.rope_theta)
    k_cache = jax.lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, ctx.length, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, ctx.length, 0, 0))
    o = decode_attention(q, k_cache, v_cache, ctx.length + 1)
    out = h + jnp.einsum("bshq,hqd->bsd", o, p["wo"]).astype(h.dtype)
    return out, {"k": k_cache, "v": v_cache}


# ============================================================ cross attention
def cross_attn_spec(cfg: ArchConfig) -> dict:
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.dh
    return {
        "ln": PSpec((d,), ("embed",), init="ones"),
        "wq": PSpec((d, h, dh), ("embed", "heads", "head_dim")),
        "wk": PSpec((d, kv, dh), ("embed", "kv_heads", "head_dim")),
        "wv": PSpec((d, kv, dh), ("embed", "kv_heads", "head_dim")),
        "wo": PSpec((h, dh, d), ("heads", "head_dim", "embed")),
        "gate": PSpec((1,), (None,), init="zeros"),
    }


def cross_attn_apply(p: dict, h: jax.Array, ctx: Ctx, cfg: ArchConfig) -> jax.Array:
    x = rms_norm(h, p["ln"], cfg.norm_eps)
    mem = ctx.memory
    q = jnp.einsum("bsd,dhq->bshq", x, p["wq"])
    k = jnp.einsum("bsd,dhq->bshq", mem, p["wk"])
    v = jnp.einsum("bsd,dhq->bshq", mem, p["wv"])
    o = attention(q, k, v, causal=False)
    g = jnp.tanh(p["gate"].astype(jnp.float32)).astype(h.dtype)
    return h + g * jnp.einsum("bshq,hqd->bsd", o, p["wo"]).astype(h.dtype)


# ==================================================================== MLA attn
def mla_spec(cfg: ArchConfig) -> dict:
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    qdim = m.nope_head_dim + m.rope_head_dim
    spec = {
        "ln": PSpec((d,), ("embed",), init="ones"),
        "w_dkv": PSpec((d, m.kv_lora_rank + m.rope_head_dim), ("embed", "kv_lora")),
        "kv_ln": PSpec((m.kv_lora_rank,), (None,), init="ones"),
        "w_uk": PSpec((m.kv_lora_rank, H, m.nope_head_dim),
                      ("kv_lora", "heads", "head_dim")),
        "w_uv": PSpec((m.kv_lora_rank, H, m.v_head_dim),
                      ("kv_lora", "heads", "head_dim")),
        "wo": PSpec((H, m.v_head_dim, d), ("heads", "head_dim", "embed")),
    }
    if m.q_lora_rank:
        spec["w_dq"] = PSpec((d, m.q_lora_rank), ("embed", "q_lora"))
        spec["q_ln"] = PSpec((m.q_lora_rank,), (None,), init="ones")
        spec["w_uq"] = PSpec((m.q_lora_rank, H, qdim), ("q_lora", "heads", "head_dim"))
    else:
        spec["w_q"] = PSpec((d, H, qdim), ("embed", "heads", "head_dim"))
    return spec


def _mla_qkv(p: dict, x: jax.Array, ctx: Ctx, cfg: ArchConfig, positions):
    m = cfg.mla
    H = cfg.n_heads
    if "w_dq" in p:
        cq = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["w_dq"]), p["q_ln"], cfg.norm_eps)
        q = jnp.einsum("bsr,rhq->bshq", cq, p["w_uq"])
    else:
        q = jnp.einsum("bsd,dhq->bshq", x, p["w_q"])
    q_nope, q_rope = q[..., :m.nope_head_dim], q[..., m.nope_head_dim:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    ckv_full = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"])
    c_kv = rms_norm(ckv_full[..., :m.kv_lora_rank], p["kv_ln"], cfg.norm_eps)
    k_rope = rope(ckv_full[..., None, m.kv_lora_rank:], positions, cfg.rope_theta)
    return q_nope, q_rope, c_kv, k_rope[:, :, 0]


def mla_apply(p: dict, h: jax.Array, ctx: Ctx, cfg: ArchConfig) -> jax.Array:
    """Full-sequence MLA (decompressed K/V — training/prefill path)."""
    m = cfg.mla
    x = rms_norm(h, p["ln"], cfg.norm_eps)
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, x, ctx, cfg, ctx.positions)
    k_nope = shard_act(jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uk"]),
                       "dp", None, "model", None)
    v = shard_act(jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uv"]),
                  "dp", None, "model", None)
    # concat_rows (not jnp.concatenate): operands are (dp, -, model, -)
    # sharded and jax 0.4.37 miscompiles sharded concatenate on multi-axis
    # meshes — see repro.dist.sharding.concat_rows
    mla_labels = ("dp", None, "model", None)
    k = concat_rows(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None],
                                  (*k_rope.shape[:2], cfg.n_heads, m.rope_head_dim))],
        axis=-1, labels=mla_labels)
    q = concat_rows([q_nope, q_rope], axis=-1, labels=mla_labels)
    scale = 1.0 / np.sqrt(m.nope_head_dim + m.rope_head_dim)
    q = shard_act(q, "dp", None, "model", None)
    chunk = cfg.attn_chunk if h.shape[1] > 2 * cfg.attn_chunk else 0
    o = attention(q, k, v, causal=True, kv_chunk=chunk, softmax_scale=scale)
    o = shard_act(o, "dp", None, "model", None)
    out = h + jnp.einsum("bshk,hkd->bsd", o, p["wo"]).astype(h.dtype)
    return shard_res(out)


def mla_cache_spec(cfg: ArchConfig, batch: int, max_seq: int) -> dict:
    m = cfg.mla
    return {
        "c_kv": PSpec((batch, max_seq, m.kv_lora_rank),
                      ("batch", "cache_seq", None), init="zeros"),
        "k_rope": PSpec((batch, max_seq, m.rope_head_dim),
                        ("batch", "cache_seq", None), init="zeros"),
    }


def mla_prefill_cache(p: dict, h: jax.Array, ctx: Ctx, cfg: ArchConfig,
                      max_seq: int):
    m = cfg.mla
    x = rms_norm(h, p["ln"], cfg.norm_eps)
    _, _, c_kv, k_rope = _mla_qkv(p, x, ctx, cfg, ctx.positions)
    out = mla_apply(p, h, ctx, cfg)
    pad = max_seq - c_kv.shape[1]
    if pad:
        c_kv = jnp.pad(c_kv, ((0, 0), (0, pad), (0, 0)))
        k_rope = jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0)))
    return out, {"c_kv": c_kv.astype(BF16), "k_rope": k_rope.astype(BF16)}


def mla_decode(p: dict, h: jax.Array, cache: dict, ctx: Ctx, cfg: ArchConfig):
    """Absorbed MLA decode: attention in the compressed rank-r space."""
    m = cfg.mla
    x = rms_norm(h, p["ln"], cfg.norm_eps)
    pos = ctx.length[None, None] * jnp.ones(h.shape[:2], jnp.int32)
    q_nope, q_rope, c_kv_new, k_rope_new = _mla_qkv(p, x, ctx, cfg, pos)
    c_cache = jax.lax.dynamic_update_slice(
        cache["c_kv"], c_kv_new.astype(cache["c_kv"].dtype), (0, ctx.length, 0))
    r_cache = jax.lax.dynamic_update_slice(
        cache["k_rope"], k_rope_new.astype(cache["k_rope"].dtype),
        (0, ctx.length, 0))
    # absorb W_uk into q: q_eff (B,H,r)
    q_eff = jnp.einsum("bshk,rhk->bshr", q_nope, p["w_uk"])
    scale = 1.0 / np.sqrt(m.nope_head_dim + m.rope_head_dim)
    logits = (jnp.einsum("bshr,btr->bhst", q_eff, c_cache,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bshk,btk->bhst", q_rope, r_cache,
                           preferred_element_type=jnp.float32)) * scale
    t = c_cache.shape[1]
    posi = jax.lax.broadcasted_iota(jnp.int32, (t,), 0)
    logits = jnp.where((posi < ctx.length + 1)[None, None, None], logits, -1e30)
    pattn = jax.nn.softmax(logits, axis=-1)
    o_c = jnp.einsum("bhst,btr->bshr", pattn.astype(c_cache.dtype), c_cache)
    o = jnp.einsum("bshr,rhk->bshk", o_c, p["w_uv"])
    out = h + jnp.einsum("bshk,hkd->bsd", o, p["wo"]).astype(h.dtype)
    return out, {"c_kv": c_cache, "k_rope": r_cache}


# ------------------------------------------------ gather-mirrored MoE VJPs
# The VJP of a gather is a scatter, which GSPMD resolves by replicating the
# operand and all-reducing (observed: ~7 GB/layer of f32 collectives on
# deepseek-v2-lite). Dispatch/combine are index bijections (plus drops), so
# each backward is itself a gather — these custom VJPs keep the whole MoE
# data path scatter-free (EXPERIMENTS.md §Perf iteration 3).

@jax.custom_vjp
def _dispatch_gather(xpad, slot_tok, e_c, pos_c, inv_order):
    """(G,s+1,d) rows -> (G,E,C,d) expert slots (slot_tok sentinel = s)."""
    G = xpad.shape[0]
    gidx = jnp.arange(G, dtype=jnp.int32)[:, None, None]
    return xpad[gidx, slot_tok]


def _dispatch_gather_fwd(xpad, slot_tok, e_c, pos_c, inv_order):
    out = _dispatch_gather(xpad, slot_tok, e_c, pos_c, inv_order)
    return out, (e_c, pos_c, inv_order, xpad.shape[1] - 1)


def _dispatch_gather_bwd(res, d_ebuf):
    e_c, pos_c, inv_order, s = res
    G, E, C, dd = d_ebuf.shape
    sk = e_c.shape[1]
    k = sk // s
    dpad = jnp.pad(d_ebuf, ((0, 0), (0, 1), (0, 1), (0, 0)))
    gidx = jnp.arange(G, dtype=jnp.int32)[:, None]
    d_rows = shard_act(dpad[gidx, e_c, pos_c], "dp", None, None)   # (G,sk,d)
    d_unsrt = jnp.take_along_axis(d_rows, inv_order[..., None], axis=1)
    d_x = d_unsrt.reshape(G, s, k, dd).sum(axis=2)
    d_xpad = jnp.pad(d_x, ((0, 0), (0, 1), (0, 0)))
    return (d_xpad, None, None, None, None)


_dispatch_gather.defvjp(_dispatch_gather_fwd, _dispatch_gather_bwd)


@jax.custom_vjp
def _combine_gather(ypad, e_c, pos_c, slot_asn):
    """(G,E+1,C+1,d) expert outputs -> (G,sk,d) per-assignment rows."""
    gidx = jnp.arange(ypad.shape[0], dtype=jnp.int32)[:, None]
    return ypad[gidx, e_c, pos_c]


def _combine_gather_fwd(ypad, e_c, pos_c, slot_asn):
    return _combine_gather(ypad, e_c, pos_c, slot_asn), (slot_asn,)


def _combine_gather_bwd(res, d_rows):
    (slot_asn,) = res
    G, sk, dd = d_rows.shape
    dpad = jnp.pad(d_rows, ((0, 0), (0, 1), (0, 0)))   # row sk = zeros
    gidx = jnp.arange(G, dtype=jnp.int32)[:, None, None]
    d_ypad = shard_act(dpad[gidx, slot_asn], "dp", None, None, None)
    return (d_ypad, None, None, None)


_combine_gather.defvjp(_combine_gather_fwd, _combine_gather_bwd)


@jax.custom_vjp
def _permute(x, idx, inv_idx):
    """take_along_axis over a permutation; backward is the inverse gather."""
    return jnp.take_along_axis(x, idx[..., None], axis=1)


def _permute_fwd(x, idx, inv_idx):
    return _permute(x, idx, inv_idx), (inv_idx,)


def _permute_bwd(res, d):
    (inv_idx,) = res
    return (jnp.take_along_axis(d, inv_idx[..., None], axis=1), None, None)


_permute.defvjp(_permute_fwd, _permute_bwd)


# ===================================================================== MLPs
def mlp_spec(cfg: ArchConfig, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    return {
        "ln": PSpec((d,), ("embed",), init="ones"),
        "w_gate": PSpec((d, f), ("embed", "mlp")),
        "w_up": PSpec((d, f), ("embed", "mlp")),
        "w_down": PSpec((f, d), ("mlp", "embed")),
    }


def mlp_apply(p: dict, h: jax.Array, cfg: ArchConfig) -> jax.Array:
    x = rms_norm(h, p["ln"], cfg.norm_eps)
    out = h + swiglu(x, p["w_gate"], p["w_up"], p["w_down"])
    return shard_res(out)


def moe_spec(cfg: ArchConfig) -> dict:
    mo = cfg.moe
    d, E, fe = cfg.d_model, mo.num_experts, mo.d_expert
    spec = {
        "ln": PSpec((d,), ("embed",), init="ones"),
        "router": PSpec((d, E), ("embed", None), dtype=jnp.float32),
        # f (not d) carries the FSDP shard: the gate/up expert einsums then
        # contract an unsharded d against (E: model, f: data)-sharded weights
        # with NO per-microbatch weight all-gathers; only the (E,G,C,d)
        # output needs a reduce-scatter (§Perf iteration 4)
        "we_gate": PSpec((E, d, fe), ("experts", None, "moe_mlp")),
        "we_up": PSpec((E, d, fe), ("experts", None, "moe_mlp")),
        "we_down": PSpec((E, fe, d), ("experts", "moe_mlp", None)),
    }
    if mo.num_shared:
        fs = mo.d_expert * mo.num_shared
        spec["ws_gate"] = PSpec((d, fs), ("embed", "mlp"))
        spec["ws_up"] = PSpec((d, fs), ("embed", "mlp"))
        spec["ws_down"] = PSpec((fs, d), ("mlp", "embed"))
    return spec


def moe_apply(p: dict, h: jax.Array, cfg: ArchConfig) -> jax.Array:
    """Group-wise sort-based dropping dispatch (expert parallelism).

    Tokens stay grouped by batch row (groups shard over the data axes); each
    group sorts its (S·k) assignments locally, scatters into a per-group
    (E, C, d) buffer, and a single transpose to the (E: model, G: data)
    layout is the EP all-to-all. No (T,E,C) one-hot dispatch einsum — HLO
    FLOPs stay ≈ real expert FLOPs (DESIGN.md §7.4). Groups are processed in
    `dispatch_chunks` sequential chunks to cap the dispatch working set
    (and pipeline the EP exchange against expert compute).
    """
    mo = cfg.moe
    b, s, d = h.shape
    E, k = mo.num_experts, mo.top_k
    # SP -> full-sequence boundary: one explicit all-gather of the S axis
    # here; all dispatch arithmetic below then stays local to its data shard
    # (EXPERIMENTS.md §Perf iteration 2)
    x = shard_act(rms_norm(h, p["ln"], cfg.norm_eps), "dp", None, None)

    cap = int(np.ceil(s * k * mo.capacity_factor / E / 4.0)) * 4
    cap = max(cap, min(k, s * k))

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, k)                        # (b,s,k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    def group_dispatch(xg, eg, gg):
        """xg (G,s,d), eg (G,s,k), gg (G,s,k) -> MoE output (G,s,d).

        Gather-only data movement: the only scatter is the int32 slot map
        (G,E+1,C+1); token rows move via batched gathers and the combine is
        an inverse-permutation gather + reshape-sum — shapes GSPMD partitions
        cleanly on the group (data) and expert (model) dims.
        """
        G = xg.shape[0]
        sk = s * k
        e_flat = eg.reshape(G, sk)
        g_flat = gg.reshape(G, sk)
        tok_flat = jnp.repeat(jnp.arange(s, dtype=jnp.int32), k)[None]
        tok_flat = jnp.broadcast_to(tok_flat, (G, sk))
        order = jnp.argsort(e_flat, axis=-1)
        inv_order = jnp.argsort(order, axis=-1)
        e_srt = jnp.take_along_axis(e_flat, order, -1)
        t_srt = jnp.take_along_axis(tok_flat, order, -1)
        # position within expert, per group
        onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)      # (G,sk,E)
        counts = onehot.sum(axis=1)                              # (G,E)
        starts = jnp.cumsum(counts, axis=-1) - counts
        pos = (jnp.arange(sk, dtype=jnp.int32)[None]
               - jnp.take_along_axis(starts, e_srt, -1))
        keep = pos < cap
        pos_c = jnp.where(keep, pos, cap).astype(jnp.int32)
        e_c = jnp.where(keep, e_srt, E).astype(jnp.int32)

        gidx = jnp.arange(G, dtype=jnp.int32)[:, None]
        slot_tok = jnp.full((G, E + 1, cap + 1), s, jnp.int32)
        slot_tok = slot_tok.at[gidx, e_c, pos_c].set(t_srt)      # int-only scatter
        slot_asn = jnp.full((G, E + 1, cap + 1), sk, jnp.int32)
        slot_asn = slot_asn.at[gidx, e_c, pos_c].set(
            jnp.broadcast_to(jnp.arange(sk, dtype=jnp.int32)[None], (G, sk)))
        xpad = jnp.pad(xg, ((0, 0), (0, 1), (0, 0)))             # row s = zeros
        # gather stays LOCAL to each data shard (G batched); only the compact
        # (E,G,C,d) buffer crosses the mesh (§Perf iterations 1-3)
        ebuf = shard_act(
            _dispatch_gather(xpad, slot_tok[:, :E, :cap], e_c, pos_c,
                             inv_order), "dp", None, None, None)  # (G,E,C,d)
        # EP exchange in two cheap steps: slice E per model rank (local — the
        # buffer is model-replicated), then a sharding-preserving transpose
        ebuf = shard_act(ebuf, "dp", "model", None, None)
        ebuf = shard_act(jnp.swapaxes(ebuf, 0, 1), "model", "dp", None, None)
        gg_ = jnp.einsum("egcd,edf->egcf", ebuf, p["we_gate"])
        uu = jnp.einsum("egcd,edf->egcf", ebuf, p["we_up"])
        yy = jnp.einsum("egcf,efd->egcd", jax.nn.silu(gg_) * uu, p["we_down"])
        yb = shard_act(jnp.swapaxes(yy, 0, 1), "dp", None, None, None)
        ypad = jnp.pad(yb, ((0, 0), (0, 1), (0, 1), (0, 0)))     # (G,E+1,C+1,d)
        y_srt = shard_act(_combine_gather(ypad, e_c, pos_c, slot_asn),
                          "dp", None, None)
        g_srt = jnp.take_along_axis(g_flat, order, -1)
        y_srt = y_srt * (g_srt * keep)[..., None].astype(yy.dtype)
        y_unsrt = shard_act(_permute(y_srt, inv_order, order), "dp", None, None)
        return y_unsrt.reshape(G, s, k, d).sum(axis=2)

    # keep >= one group per data shard in every chunk (else GSPMD replicates)
    nchunk = max(1, min(mo.dispatch_chunks, b // max(dp_axis_size(), 1)))
    while b % nchunk:
        nchunk -= 1
    if nchunk > 1:
        # chunk dim is sequential (lax.map); groups stay data-sharded
        xr = shard_act(x.reshape(nchunk, b // nchunk, s, d),
                       None, "dp", None, None)
        er = shard_act(eidx.reshape(nchunk, b // nchunk, s, k),
                       None, "dp", None, None)
        gr = shard_act(gates.reshape(nchunk, b // nchunk, s, k),
                       None, "dp", None, None)
        # remat the chunk body: its dispatch buffers are recomputed in the
        # backward instead of being stacked across chunks by scan autodiff
        out = jax.lax.map(jax.checkpoint(lambda a: group_dispatch(*a)),
                          (xr, er, gr))
        out = out.reshape(b, s, d)
    else:
        out = group_dispatch(x, eidx, gates)

    out = shard_act(out, "dp", None, None)
    if mo.num_shared:
        out = out + swiglu(x, p["ws_gate"], p["ws_up"], p["ws_down"])
    return shard_res(h + out.astype(h.dtype))
