"""Deterministic, resumable synthetic token pipeline for the LM examples.

Counter-based (Philox) generation: batch ``i`` is a pure function of
(seed, i), so resuming from a checkpointed step counter reproduces the exact
stream — no state files, no data-order drift across restarts, and any host
can generate any shard (elastic-friendly). Sequences follow a Zipf unigram
model with markovian repetition so the loss actually decreases.
"""
from __future__ import annotations

import numpy as np


class TokenStream:
    """Infinite iterator of synthetic LM batches, resumable by step counter.

    Batch ``i`` is a pure function of ``(seed, i)`` (counter-based Philox),
    so checkpointing just the ``step`` integer reproduces the exact stream.
    Yields ``{"tokens": (batch, seq_len) int32, "loss_mask": float32}``.
    """

    def __init__(self, vocab: int, batch: int, seq_len: int, *, seed: int = 0,
                 zipf_a: float = 1.2, repeat_p: float = 0.3):
        """Set vocab/batch/seq shape and the Zipf(zipf_a) unigram model."""
        self.vocab = vocab
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed
        self.step = 0
        ranks = np.arange(1, vocab + 1, dtype=np.float64)
        p = ranks ** (-zipf_a)
        self.probs = p / p.sum()
        self.repeat_p = repeat_p

    def __iter__(self):
        """Return self (infinite iterator)."""
        return self

    def __next__(self) -> dict:
        """Generate batch ``self.step`` and advance the counter."""
        rng = np.random.Generator(np.random.Philox(key=self.seed,
                                                   counter=self.step))
        toks = rng.choice(self.vocab, size=(self.batch, self.seq_len),
                          p=self.probs).astype(np.int32)
        # markovian repetition: with prob repeat_p copy the previous token
        rep = rng.random((self.batch, self.seq_len)) < self.repeat_p
        for t in range(1, self.seq_len):
            toks[:, t] = np.where(rep[:, t], toks[:, t - 1], toks[:, t])
        self.step += 1
        return {"tokens": toks,
                "loss_mask": np.ones((self.batch, self.seq_len), np.float32)}

    # resumable: the counter IS the state
    def state_dict(self) -> dict:
        """Checkpointable state: just the step counter."""
        return {"step": self.step}

    def load_state_dict(self, state: dict) -> None:
        """Resume the stream from a ``state_dict()`` snapshot."""
        self.step = int(state["step"])
