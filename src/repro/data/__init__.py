from repro.data.tokens import TokenStream
from repro.data.prefetch import Prefetcher

__all__ = ["TokenStream", "Prefetcher"]
