"""Data-loading layer: token streams and the async subgraph pipeline."""
from repro.data.tokens import TokenStream
from repro.data.prefetch import Prefetcher, SubgraphPipeline

__all__ = ["TokenStream", "Prefetcher", "SubgraphPipeline"]
