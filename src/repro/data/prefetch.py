"""Host-side prefetch: overlap batch construction with device compute."""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator


class Prefetcher:
    """Background-thread prefetch with a bounded buffer (double buffering
    by default). `close()` (or GC) stops the worker."""

    def __init__(self, source: Iterator, depth: int = 2):
        self.source = source
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self) -> None:
        try:
            for item in self.source:
                if self._stop.is_set():
                    return
                self.q.put(item)
        finally:
            self.q.put(StopIteration)

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if item is StopIteration:
            raise StopIteration
        return item

    def close(self) -> None:
        self._stop.set()
