"""Host-side prefetch: overlap batch construction with device compute."""
from __future__ import annotations

import queue
import threading
from typing import Iterator


class _Done:
    """Private end-of-stream sentinel (unique object, never yielded by a
    source — unlike e.g. the StopIteration class itself)."""


class _Raised:
    """Wraps an exception raised inside the worker for re-raise in the
    consumer thread."""

    def __init__(self, exc: BaseException):
        self.exc = exc


class Prefetcher:
    """Background-thread prefetch with a bounded buffer (double buffering
    by default).

    * Items are yielded in source order; at most ``depth`` batches are ever
      buffered ahead of the consumer (bounded lookahead, so host memory for
      batch construction stays O(depth)).
    * An exception raised by the source propagates to the consumer from
      ``__next__`` — after all items produced before it have been consumed.
    * ``close()`` stops the worker thread promptly even when it is blocked
      in a full-queue ``put`` and joins it; it is idempotent and is also
      called on GC. Iterating after ``close()`` raises ``StopIteration``.
    """

    # worker wakes up at this period to notice close() while blocked on a
    # full queue; latency of close(), not of the data path
    _PUT_POLL_S = 0.05

    def __init__(self, source: Iterator, depth: int = 2):
        if depth < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        self.source = source
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._exhausted = False
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _put(self, item) -> bool:
        """Blocking put that aborts (returns False) once close() is called."""
        while not self._stop.is_set():
            try:
                self.q.put(item, timeout=self._PUT_POLL_S)
                return True
            except queue.Full:
                continue
        return False

    def _worker(self) -> None:
        try:
            for item in self.source:
                if not self._put(item):
                    return
        except BaseException as exc:  # noqa: BLE001 — re-raised in consumer
            self._put(_Raised(exc))
            return
        self._put(_Done)

    def __iter__(self):
        return self

    def __next__(self):
        if self._exhausted:
            raise StopIteration
        while True:
            if self._stop.is_set():
                raise StopIteration
            try:
                item = self.q.get(timeout=self._PUT_POLL_S)
                break
            except queue.Empty:
                continue
        if item is _Done:
            self._exhausted = True
            raise StopIteration
        if isinstance(item, _Raised):
            self._exhausted = True
            raise item.exc
        return item

    def close(self) -> None:
        self._stop.set()
        # drain so a worker blocked mid-put sees _stop on its next poll and
        # the queue's buffered batches are released promptly
        while True:
            try:
                self.q.get_nowait()
            except queue.Empty:
                break
        self._thread.join(timeout=5.0)

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass
