"""Host-side prefetch: overlap batch construction with device compute.

Two layers live here (DESIGN.md §9):

* :class:`Prefetcher` — a generic background-thread iterator wrapper with a
  bounded buffer, in-order delivery, exception propagation and prompt
  ``close()``. It knows nothing about graphs.
* :class:`SubgraphPipeline` — the LMC training pipeline built on top of it: a
  thread pool pulls schedule slots from ``ClusterSampler.clusters_at`` (a pure
  function of the slot index, so worker arrival order cannot perturb the
  stream), builds padded ``Batch`` + fixed-capacity ELL buckets on the host,
  hands them through the ``Prefetcher`` queue, and double-buffers the
  host→device transfer: while the consumer runs step k, the transfer for the
  next batch is already staged with ``jax.device_put``. ``recycle=ρ`` reuses
  each sampled subgraph for ρ consecutive steps (LazyGNN-style minibatch
  recycling) before resampling; LMC's bounded-staleness historical stores
  keep this within the Thm 2 staleness budget because the store-refresh path
  is unchanged — every recycled step still rewrites its store rows.
"""
from __future__ import annotations

import itertools
import queue
import threading
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Iterator, Optional


class _Done:
    """Private end-of-stream sentinel (unique object, never yielded by a
    source — unlike e.g. the StopIteration class itself)."""


class _Raised:
    """Wraps an exception raised inside the worker for re-raise in the
    consumer thread."""

    def __init__(self, exc: BaseException):
        self.exc = exc


class Prefetcher:
    """Background-thread prefetch with a bounded buffer (double buffering
    by default).

    * Items are yielded in source order; at most ``depth`` batches are ever
      buffered ahead of the consumer (bounded lookahead, so host memory for
      batch construction stays O(depth)).
    * An exception raised by the source propagates to the consumer from
      ``__next__`` — after all items produced before it have been consumed.
    * ``close()`` stops the worker thread promptly even when it is blocked
      in a full-queue ``put`` and joins it; it is idempotent and is also
      called on GC. Iterating after ``close()`` raises ``StopIteration``.

    Thread-safety: one producer (the internal worker) and one consumer
    thread; ``__next__``/``poll`` must not be called concurrently from
    multiple threads.
    """

    # worker wakes up at this period to notice close() while blocked on a
    # full queue; latency of close(), not of the data path
    _PUT_POLL_S = 0.05

    def __init__(self, source: Iterator, depth: int = 2):
        """Start prefetching from ``source`` with a ``depth``-item buffer."""
        if depth < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        self.source = source
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._held = None   # terminal item peeked by poll(), kept in order
        self._exhausted = False
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _put(self, item) -> bool:
        """Blocking put that aborts (returns False) once close() is called."""
        while not self._stop.is_set():
            try:
                self.q.put(item, timeout=self._PUT_POLL_S)
                return True
            except queue.Full:
                continue
        return False

    def _worker(self) -> None:
        try:
            for item in self.source:
                if not self._put(item):
                    return
        except BaseException as exc:  # noqa: BLE001 — re-raised in consumer
            self._put(_Raised(exc))
            return
        self._put(_Done)

    def __iter__(self):
        """Return self (single-consumer iterator)."""
        return self

    def __next__(self):
        """Next item in source order; blocks until one is buffered."""
        if self._exhausted:
            raise StopIteration
        if self._held is not None:
            item, self._held = self._held, None
            return self._resolve(item)
        while True:
            if self._stop.is_set():
                raise StopIteration
            try:
                item = self.q.get(timeout=self._PUT_POLL_S)
                break
            except queue.Empty:
                continue
        return self._resolve(item)

    def poll(self):
        """Non-blocking variant of ``__next__``: an item if one is already
        buffered, else ``None`` (also ``None`` at end-of-stream).

        Terminal items (end-of-stream, or an exception raised by the
        source) are *held back* rather than consumed here, so they surface
        from the next blocking ``__next__`` at their exact position in the
        stream. The pipeline uses poll() to opportunistically stage the next
        device transfer without stalling the train step — an error for a
        later slot must not fire while an earlier slot is being fetched.
        """
        if self._exhausted or self._stop.is_set() or self._held is not None:
            return None
        try:
            item = self.q.get_nowait()
        except queue.Empty:
            return None
        if item is _Done or isinstance(item, _Raised):
            self._held = item
            return None
        return item

    def _resolve(self, item):
        """Map a queue item to (value | StopIteration | re-raised error)."""
        if item is _Done:
            self._exhausted = True
            raise StopIteration
        if isinstance(item, _Raised):
            self._exhausted = True
            raise item.exc
        return item

    def close(self) -> None:
        """Stop and join the worker; idempotent, also invoked on GC."""
        self._stop.set()
        # drain so a worker blocked mid-put sees _stop on its next poll and
        # the queue's buffered batches are released promptly
        while True:
            try:
                self.q.get_nowait()
            except queue.Empty:
                break
        self._thread.join(timeout=5.0)

    def __del__(self):
        """Best-effort close when the prefetcher is garbage collected."""
        try:
            self.close()
        except Exception:
            pass


class SubgraphPipeline:
    """Async subgraph sampling pipeline with minibatch recycling.

    Yields device-ready ``repro.core.Batch`` objects, one per *training
    step*. Internally a ``ThreadPoolExecutor`` builds schedule slots ahead of
    the consumer (``sampler.build_batch`` + ``host_batch``: pure numpy, no
    JAX calls on worker threads), a :class:`Prefetcher` buffers up to
    ``depth`` built batches, and the consumer side keeps one extra batch
    staged on device (``jax.device_put`` issued while the previous step is
    still running — double-buffered host→device transfer).

    Determinism contract: the stream is a pure function of
    ``(sampler.seed, mode, recycle, step index)``. Slot ``i`` (steps
    ``[i*recycle, (i+1)*recycle)``) always carries the clusters
    ``sampler.clusters_at(i, mode=mode)``, regardless of ``depth``,
    ``workers`` or thread scheduling; ``depth=0`` builds the identical stream
    synchronously in the consumer thread. Resuming from ``start_step`` k
    replays exactly the tail of a run started at 0 (checkpoint recovery).

    Recycling (``recycle=ρ > 1``): each built subgraph is yielded for ρ
    consecutive steps before the next slot is fetched, amortizing the host
    sampling + bucketing cost 1/ρ. Under ``mode="epoch"`` an "epoch" becomes
    ρ·B/c steps but still visits every cluster exactly once per B/c distinct
    slots. Safe for LMC because the historical stores are refreshed by every
    step — including recycled ones — so staleness stays within the Thm 2
    ρ-term (DESIGN.md §9 discusses the bound).

    Lifecycle: iterate (``for batch in pipe`` / ``next(pipe)``), then
    ``close()`` — or use it as a context manager, which closes on exit even
    when the consumer raises mid-epoch. A worker-side exception surfaces in
    the consumer at the failed slot's position in the stream; buffered
    earlier batches drain first. After ``close()`` iteration raises
    ``StopIteration``.

    Thread-safety: single consumer thread; the sampler's schedule API
    (``clusters_at``/``build_batch``) is called concurrently from workers
    and must stay read-only (``ClusterSampler``'s is).
    """

    def __init__(self, sampler, *, backend: str = "segment", depth: int = 2,
                 workers: int = 2, recycle: int = 1, mode: str = "uniform",
                 start_step: int = 0, num_steps: Optional[int] = None,
                 ell_buckets=(8, 32, 128),
                 build_hook: Optional[Callable[[int], None]] = None):
        """Configure and (for ``depth >= 1``) start the background pipeline.

        Args:
            sampler: a ``ClusterSampler`` (any object with ``clusters_at`` +
                ``build_batch``); its schedule API must be thread-safe.
            backend: ``"segment"``, ``"ell"`` or ``"ti"`` — whether workers
                also bucket each batch's adjacency into the Pallas kernels'
                ELL layout (``"ti"`` additionally rides the subgraph's
                message-invariance scales along; see core/lmc.host_batch).
            depth: prefetch queue depth. ``0`` disables all threading: the
                synchronous fallback path, same stream (tiny graphs,
                debugging). ``>= 1`` bounds host lookahead to
                ``depth + workers`` built batches plus one staged on device.
            workers: thread-pool size for host-side batch construction.
            recycle: ρ — consecutive steps each sampled subgraph is reused.
            mode: ``"uniform"`` (iid slots, Alg. 1 line 4) or ``"epoch"``
                (shuffled epochs, every cluster once per B/c slots).
            start_step: global step to resume from (slot ``start_step //
                recycle``, mid-recycle-window offsets included).
            num_steps: stop after this many yields (``None`` = unbounded).
            ell_buckets: ELL degree-bucket sizes for ``backend="ell"``.
            build_hook: optional ``hook(slot)`` invoked (on the building
                thread) before each slot is built — the fault-injection
                seam (``train.health.FaultPlan.pipeline_hook``): raising
                here surfaces at that slot's position in the stream like
                any worker exception, and the consumer can rebuild the
                pipeline at the same step for a deterministic retry.
        """
        if depth < 0:
            raise ValueError(f"depth must be >= 0, got {depth}")
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        if recycle < 1:
            raise ValueError(f"recycle must be >= 1, got {recycle}")
        if start_step < 0:
            raise ValueError(f"start_step must be >= 0, got {start_step}")
        self.sampler = sampler
        self.backend = backend
        self.depth = int(depth)
        self.workers = int(workers)
        self.recycle = int(recycle)
        self.mode = mode
        self.ell_buckets = ell_buckets
        self.build_hook = build_hook
        self._step = int(start_step)
        self._end_step = None if num_steps is None else self._step + int(num_steps)
        self._cur_slot = -1
        self._cur_batch = None
        self._staged = None          # device batch for the next slot
        self._closed = False
        self._pool: Optional[ThreadPoolExecutor] = None
        self._pf: Optional[Prefetcher] = None
        if self.depth >= 1:
            first_slot = self._step // self.recycle
            end_slot = (None if self._end_step is None
                        else -(-self._end_step // self.recycle))
            self._pool = ThreadPoolExecutor(
                max_workers=self.workers,
                thread_name_prefix="subgraph-pipeline")
            self._pf = Prefetcher(self._built_stream(first_slot, end_slot),
                                  depth=self.depth)

    # ------------------------------------------------------------- producer
    def _build_host(self, slot: int):
        """Worker-side: schedule slot -> host (numpy) Batch. Pure numpy."""
        from repro.core.lmc import host_batch
        if self.build_hook is not None:
            self.build_hook(slot)
        cids = self.sampler.clusters_at(slot, mode=self.mode)
        sg = self.sampler.build_batch(cids)
        return host_batch(sg, backend=self.backend,
                          ell_buckets=self.ell_buckets)

    def _built_stream(self, first_slot: int, end_slot: Optional[int]):
        """Generator the Prefetcher drives: in-order built host batches.

        Keeps up to ``workers`` build futures in flight; ``.result()``
        re-raises worker exceptions in slot order so the Prefetcher's
        exception contract holds unchanged.
        """
        slots = (itertools.count(first_slot) if end_slot is None
                 else iter(range(first_slot, end_slot)))
        pending: deque = deque()
        try:
            while True:
                while len(pending) < self.workers:
                    try:
                        s = next(slots)
                    except StopIteration:
                        break
                    pending.append(self._pool.submit(self._build_host, s))
                if not pending:
                    return
                yield pending.popleft().result()
        finally:
            for f in pending:
                f.cancel()

    # ------------------------------------------------------------- consumer
    def _fetch_next_slot(self):
        """Device batch for the next schedule slot, advancing the stream.

        With prefetch: take the staged transfer if one exists, else block on
        the queue + ``device_put``; then opportunistically stage the transfer
        for the following slot (this is the device-side double buffer).
        Without prefetch (``depth=0``): build + transfer inline.
        """
        import jax
        if self._pf is None:
            slot = self._step // self.recycle
            return jax.device_put(self._build_host(slot))
        if self._staged is not None:
            batch, self._staged = self._staged, None
        else:
            batch = jax.device_put(next(self._pf))   # may raise StopIteration
        nxt = self._pf.poll()
        if nxt is not None:
            self._staged = jax.device_put(nxt)
        return batch

    def __iter__(self):
        """Return self (single-consumer iterator)."""
        return self

    def __next__(self):
        """Device Batch for the next training step (recycling-aware)."""
        if self._closed:
            raise StopIteration
        if self._end_step is not None and self._step >= self._end_step:
            raise StopIteration
        slot = self._step // self.recycle
        if slot != self._cur_slot:
            self._cur_batch = self._fetch_next_slot()
            self._cur_slot = slot
        self._step += 1
        return self._cur_batch

    @property
    def step(self) -> int:
        """Global index of the next step this pipeline will yield."""
        return self._step

    def close(self) -> None:
        """Shut down the queue and thread pool; idempotent, also on GC.

        Safe to call with builds still in flight (consumer raised mid-epoch):
        the Prefetcher unblocks/joins its worker, then queued-but-unstarted
        builds are cancelled and the pool joins.
        """
        if self._closed:
            return
        self._closed = True
        self._cur_batch = self._staged = None
        if self._pf is not None:
            self._pf.close()
        if self._pool is not None:
            self._pool.shutdown(wait=True, cancel_futures=True)

    def __enter__(self):
        """Context-manager entry: the pipeline itself."""
        return self

    def __exit__(self, exc_type, exc, tb):
        """Context-manager exit: always close, never swallow the exception."""
        self.close()
        return False

    def __del__(self):
        """Best-effort close when the pipeline is garbage collected."""
        try:
            self.close()
        except Exception:
            pass
