"""R001: raw jnp concatenates/stacks outside the sharding subsystem.

jax 0.4.37's partitioner miscompiles `concatenate` whenever an operand or the
result is sharded on a multi-axis mesh — the output comes back summed over the
unrelated mesh axes (observed on the (data, model) grid; DESIGN.md §4, PR 1).
`repro.dist.sharding.concat_rows` expresses the concat as dynamic-update
slices into a zeros buffer with the result sharding pinned, and `stack` &
friends lower to `concatenate`, so every such call outside `dist/sharding.py`
must either route through `concat_rows` or carry a pragma proving the
operands are replicated on every mesh (e.g. an off-mesh-only code path).
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis import astutils
from repro.analysis.engine import ModuleInfo, RawFinding, Rule

# Everything that lowers to (or wraps) a lax.concatenate. `append`/`block`
# are included because they concatenate too; numpy (host-side) calls are fine.
_BANNED = {
    "jax.numpy." + fn
    for fn in ("concatenate", "stack", "hstack", "vstack", "dstack",
               "column_stack", "row_stack", "append", "block")
} | {"jax.lax.concatenate"}

# The one module allowed to call jnp.concatenate: concat_rows' own off-mesh
# fallback (where the concat is provably unsharded).
_ALLOWED_SUFFIXES = ("dist/sharding.py",)


class ShardedConcatRule(Rule):
    id = "R001"
    name = "sharded-concat"
    doc = __doc__

    def check(self, mod: ModuleInfo) -> Iterator[RawFinding]:
        path = mod.path.replace("\\", "/")
        if path.endswith(_ALLOWED_SUFFIXES):
            return
        for node in ast.walk(mod.tree):
            qn = astutils.call_qualname(node, mod.aliases)
            if qn in _BANNED:
                short = qn.split(".")[-1]
                yield node, (
                    f"raw `{short}` outside dist/sharding.py: jax 0.4.37 "
                    "miscompiles sharded concatenates (result summed over "
                    "unrelated mesh axes). Route through "
                    "repro.dist.sharding.concat_rows, or annotate with "
                    "`# lint: ok(R001) <why operands are replicated>`")
