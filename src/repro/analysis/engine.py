"""Rule engine: module loading, pragma suppression, rule registry.

A rule is a ``Rule`` subclass with a class-level ``id``/``name``/``doc`` and a
``check(mod) -> Iterator[(node_or_span, message)]``. The engine owns
everything else: walking paths, parsing, matching ``# lint: ok(R00x) reason``
pragmas against finding spans, and the R000 meta-findings (unparseable file,
reasonless pragma).

Pragma semantics: a pragma suppresses a finding of rule ``R`` when it names
``R`` and sits on any line of the flagged statement or on the line directly
above it. The reason text is mandatory — it is the audit trail that replaces
the PR-review argument for why the site is safe; a pragma without one
suppresses nothing and is itself reported as R000.
"""
from __future__ import annotations

import ast
import dataclasses
import functools
import re
from pathlib import Path
from typing import Iterable, Iterator, Optional, Sequence, Union

from repro.analysis import astutils

PRAGMA_RE = re.compile(
    r"#\s*lint:\s*ok\(\s*(?P<rules>R\d{3}(?:\s*,\s*R\d{3})*)\s*\)\s*(?P<reason>.*)$")

Span = tuple[int, int, int]           # (line, end_line, col)
RawFinding = tuple[Union[ast.AST, Span], str]


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    end_line: int
    col: int
    message: str
    suppressed: bool = False
    reason: str = ""                  # pragma reason when suppressed

    def format(self) -> str:
        flag = " [suppressed: %s]" % self.reason if self.suppressed else ""
        return f"{self.path}:{self.line}:{self.col + 1}: {self.rule} " \
               f"{self.message}{flag}"

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class Pragma:
    line: int
    rules: tuple[str, ...]
    reason: str


class ModuleInfo:
    """One parsed source file + the lazily computed per-module indexes that
    several rules share (parent links, import aliases, pragma table)."""

    def __init__(self, path: str, source: str):
        self.path = path
        self.source = source
        self.tree: ast.Module = ast.parse(source, filename=path)

    @functools.cached_property
    def parents(self) -> dict[ast.AST, ast.AST]:
        return astutils.build_parents(self.tree)

    @functools.cached_property
    def aliases(self) -> dict[str, str]:
        return astutils.import_aliases(self.tree)

    @functools.cached_property
    def pragmas(self) -> list[Pragma]:
        out = []
        for i, line in enumerate(self.source.splitlines(), start=1):
            m = PRAGMA_RE.search(line)
            if m:
                rules = tuple(r.strip() for r in m.group("rules").split(","))
                out.append(Pragma(i, rules, m.group("reason").strip()))
        return out

    @functools.cached_property
    def _comment_only(self) -> set:
        return {i for i, ln in enumerate(self.source.splitlines(), start=1)
                if ln.lstrip().startswith("#")}

    def pragma_for(self, rule: str, line: int, end_line: int
                   ) -> Optional[Pragma]:
        """Pragma naming `rule` on a line of [line, end_line] or in the
        contiguous comment block directly above the flagged statement."""
        lo = line
        while lo - 1 in self._comment_only:
            lo -= 1
        for p in self.pragmas:
            if rule in p.rules and lo - 1 <= p.line <= end_line and p.reason:
                return p
        return None


class Rule:
    """Base class; subclasses register themselves by being imported."""

    id: str = ""
    name: str = ""
    doc: str = ""

    def check(self, mod: ModuleInfo) -> Iterator[RawFinding]:
        raise NotImplementedError

    def _span(self, where: Union[ast.AST, Span]) -> Span:
        if isinstance(where, tuple):
            return where
        return (where.lineno, getattr(where, "end_lineno", None) or
                where.lineno, getattr(where, "col_offset", 0))

    def run(self, mod: ModuleInfo) -> Iterator[Finding]:
        for where, message in self.check(mod):
            line, end_line, col = self._span(where)
            pragma = mod.pragma_for(self.id, line, end_line)
            yield Finding(self.id, mod.path, line, end_line, col, message,
                          suppressed=pragma is not None,
                          reason=pragma.reason if pragma else "")


def all_rules() -> list[Rule]:
    """The catalog, in id order. Imported lazily so `engine` has no import
    cycle with the rule modules."""
    from repro.analysis.rules_concat import ShardedConcatRule
    from repro.analysis.rules_jit import JitHazardRule
    from repro.analysis.rules_pallas import DmaPairingRule, VmemBudgetRule
    from repro.analysis.rules_queue import UnboundedQueueRule
    from repro.analysis.rules_vjp import CustomVjpArityRule
    return [ShardedConcatRule(), DmaPairingRule(), VmemBudgetRule(),
            JitHazardRule(), CustomVjpArityRule(), UnboundedQueueRule()]


def _iter_py_files(paths: Sequence[Union[str, Path]]) -> Iterator[Path]:
    for p in paths:
        p = Path(p)
        if p.is_dir():
            for f in sorted(p.rglob("*.py")):
                if "__pycache__" not in f.parts:
                    yield f
        elif p.suffix == ".py":
            yield p


def _meta_findings(mod: ModuleInfo) -> Iterator[Finding]:
    """R000: pragma hygiene — a reasonless pragma is dead weight that looks
    like an audit but records nothing, so it never suppresses and is flagged."""
    for p in mod.pragmas:
        if not p.reason:
            yield Finding("R000", mod.path, p.line, p.line, 0,
                          "pragma must carry a reason: "
                          "`# lint: ok(R00x) <why this site is safe>`")


def analyze_source(source: str, path: str = "<string>",
                   rules: Optional[Iterable[Rule]] = None) -> list[Finding]:
    """Analyze one in-memory module (test fixtures use this directly)."""
    try:
        mod = ModuleInfo(path, source)
    except SyntaxError as e:
        return [Finding("R000", path, e.lineno or 1, e.lineno or 1, 0,
                        f"could not parse: {e.msg}")]
    findings = list(_meta_findings(mod))
    for rule in (all_rules() if rules is None else rules):
        findings.extend(rule.run(mod))
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings


def run_analysis(paths: Sequence[Union[str, Path]],
                 rules: Optional[Iterable[Rule]] = None) -> list[Finding]:
    """Analyze every .py file under `paths` with the given rules (default:
    the full catalog). Returns all findings, suppressed ones included —
    callers decide what an unsuppressed finding means (CLI: exit 1)."""
    rules = list(all_rules() if rules is None else rules)
    findings: list[Finding] = []
    for f in _iter_py_files(paths):
        findings.extend(
            analyze_source(f.read_text(encoding="utf-8"), str(f), rules))
    return findings


def summarize(findings: Sequence[Finding],
              rules: Optional[Iterable[Rule]] = None) -> str:
    """Per-rule one-liners + a totals line (the check.sh summary block)."""
    rules = list(all_rules() if rules is None else rules)
    by_rule: dict[str, list[Finding]] = {r.id: [] for r in rules}
    names = {r.id: r.name for r in rules}
    for f in findings:
        by_rule.setdefault(f.rule, []).append(f)
    lines = []
    for rid in sorted(by_rule):
        fs = by_rule[rid]
        live = sum(1 for f in fs if not f.suppressed)
        supp = len(fs) - live
        lines.append(f"{rid} {names.get(rid, 'meta'):<18} "
                     f"{live:3d} finding(s), {supp:3d} suppressed")
    total = sum(1 for f in findings if not f.suppressed)
    lines.append(f"repro.analysis: {total} unsuppressed finding(s)")
    return "\n".join(lines)
