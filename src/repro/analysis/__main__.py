"""CLI: `python -m repro.analysis [paths] [--rule R00x] [--json]`.

Exit status is the contract `scripts/check.sh` builds on: 0 when every
finding is pragma-suppressed, 1 when any unsuppressed finding remains,
2 on usage errors. Findings print grep-style (`path:line:col: R00x msg`)
followed by a per-rule summary block; `--json` replaces the human output
with a machine-readable dump (summary still goes to stderr).
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.analysis.engine import all_rules, run_analysis, summarize


def main(argv=None) -> int:
    rules = all_rules()
    known = {r.id for r in rules}
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-specific static analysis: kernel/sharding "
                    "invariant checks (R001-R005, DESIGN.md §8)")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files/directories to analyze (default: src)")
    ap.add_argument("--rule", action="append", metavar="R00x",
                    help="run only the given rule id (repeatable)")
    ap.add_argument("--json", action="store_true",
                    help="emit findings as JSON on stdout")
    ap.add_argument("--show-suppressed", action="store_true",
                    help="also print pragma-suppressed findings")
    args = ap.parse_args(argv)

    if args.rule:
        bad = [r for r in args.rule if r not in known]
        if bad:
            print(f"unknown rule id(s): {', '.join(bad)} "
                  f"(known: {', '.join(sorted(known))})", file=sys.stderr)
            return 2
        rules = [r for r in rules if r.id in set(args.rule)]

    findings = run_analysis(args.paths or ["src"], rules)
    live = [f for f in findings if not f.suppressed]

    if args.json:
        json.dump([f.to_json() for f in findings], sys.stdout, indent=2)
        print()
        print(summarize(findings, rules), file=sys.stderr)
    else:
        shown = findings if args.show_suppressed else live
        for f in shown:
            print(f.format())
        if shown:
            print()
        print(summarize(findings, rules))
    return 1 if live else 0


if __name__ == "__main__":
    sys.exit(main())
