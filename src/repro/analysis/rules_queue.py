"""R006: unbounded queues / unbounded blocking in the threaded tiers.

The data pipeline (repro.data, DESIGN.md §9) and the serving tier
(repro.serve, §12) are the two places worker threads hand work across
``queue.Queue``s, and both advertise hard liveness guarantees: bounded
memory under producer/consumer speed mismatch, and no call that can block
forever on a dead peer (a hung worker must surface as a typed timeout, not
a wedged process — the whole point of the serving fault matrix). Two
constructs silently break that:

* an *unbounded* queue — ``queue.Queue()`` with no/zero ``maxsize`` (or a
  ``SimpleQueue``, which cannot be bounded): backpressure becomes unbounded
  RAM growth instead of load shedding;
* a *blocking* ``get()`` / ``put(item)`` / ``join()`` with no ``timeout=``:
  if the peer died, the caller blocks forever and the drain/shutdown
  protocol can never complete.

The call checks are shape heuristics (no type inference): a bare ``.get()``
with no arguments, a ``.put(x)`` with exactly one positional argument, or a
bare ``.join()`` — exactly the blocking queue/thread forms, and shapes that
dict/str/os.path calls never take. ``*_nowait``, ``block=False`` and any
``timeout=`` are compliant. Scope is ``src/repro/{data,serve}`` only; a
deliberate indefinite block takes the standard audit pragma:
``# lint: ok(R006) <why blocking forever here is safe>``.
"""
from __future__ import annotations

import ast
from typing import Iterator, Optional

from repro.analysis import astutils
from repro.analysis.engine import ModuleInfo, RawFinding, Rule

# queue classes whose no-maxsize construction is unbounded
_BOUNDED_CTORS = {"queue.Queue", "queue.LifoQueue", "queue.PriorityQueue"}
# queues that cannot be bounded at all
_UNBOUNDABLE_CTORS = {"queue.SimpleQueue"}

_SCOPED_DIRS = ("repro/data/", "repro/serve/")


def _in_scope(path: str) -> bool:
    p = path.replace("\\", "/")
    return any(d in p for d in _SCOPED_DIRS)


def _const(node: Optional[ast.AST]):
    return node.value if isinstance(node, ast.Constant) else None


class UnboundedQueueRule(Rule):
    id = "R006"
    name = "unbounded-queue"
    doc = __doc__

    def check(self, mod: ModuleInfo) -> Iterator[RawFinding]:
        if not _in_scope(mod.path):
            return
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            qn = astutils.call_qualname(node, mod.aliases)
            if qn in _UNBOUNDABLE_CTORS:
                yield node, (
                    f"`{qn}` cannot be bounded — backpressure becomes "
                    "unbounded memory growth. Use queue.Queue(maxsize=...) "
                    "so a full queue sheds/blocks-with-timeout instead")
                continue
            if qn in _BOUNDED_CTORS:
                maxsize = _ctor_maxsize(node)
                if maxsize is _MISSING or (isinstance(maxsize, int)
                                           and maxsize <= 0):
                    yield node, (
                        f"unbounded `{qn}()` — pass maxsize>0 so the "
                        "producer sees backpressure (shed or timeout) "
                        "instead of growing the queue without bound, or "
                        "annotate with `# lint: ok(R006) <why unbounded "
                        "is safe here>`")
                continue
            yield from self._blocking_call(node)

    def _blocking_call(self, node: ast.Call) -> Iterator[RawFinding]:
        func = node.func
        if not isinstance(func, ast.Attribute):
            return
        name = func.attr
        if name not in ("get", "put", "join"):
            return
        kwnames = {k.arg for k in node.keywords}
        if "timeout" in kwnames:
            return
        # block=False (kwarg or leading positional) is non-blocking
        for k in node.keywords:
            if k.arg == "block" and _const(k.value) is False:
                return
        if node.args and _const(node.args[0]) is False:
            return
        # shape heuristics: only the blocking queue/thread forms
        flagged = (
            (name == "get" and not node.args and not node.keywords)
            or (name == "put" and len(node.args) == 1 and not node.keywords)
            or (name == "join" and not node.args and not node.keywords))
        if flagged:
            yield node, (
                f"blocking `.{name}()` without `timeout=` can wedge forever "
                "on a dead peer — pass timeout= (poll loops keep shutdown "
                "responsive), use the *_nowait form, or annotate with "
                "`# lint: ok(R006) <why blocking indefinitely is safe>`")


_MISSING = object()


def _ctor_maxsize(node: ast.Call):
    """maxsize passed to a queue constructor: value, _MISSING, or None when
    it is a runtime expression (assumed bounded — conservative skip)."""
    if node.args:
        v = _const(node.args[0])
        return v if v is not None else None
    for k in node.keywords:
        if k.arg == "maxsize":
            v = _const(k.value)
            return v if v is not None else None
    return _MISSING
