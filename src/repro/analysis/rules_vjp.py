"""R005: fwd/bwd signature and residual-arity consistency for custom VJPs.

Every aggregation gradient in this repo flows through hand-written
`jax.custom_vjp` pairs (kernels/ops.py, models/layers.py, models/blocks.py),
and jax checks almost none of the contract statically: a bwd returning the
wrong number of cotangents, a fwd whose residual tuple got a new element
while the bwd unpack didn't, or a drifted `nondiff_argnums` all surface as
cryptic tracer errors at first differentiation — or worse, as a silently
dropped gradient when a `None` lands in the wrong cotangent slot, which for
the LMC compensation path means Thm. 2's convergence guarantee quietly no
longer applies. For each `X.defvjp(fwd, bwd)` whose pieces are resolvable in
the module, with N = len(nondiff_argnums) (leading positions only — jax
passes those values positionally to both fwd and bwd):

  * fwd takes exactly as many parameters as the primal;
  * bwd takes exactly N + 2 parameters (nondiffs…, residuals, cotangent);
  * fwd returns a 2-tuple `(out, residuals)` wherever its return is a
    literal tuple;
  * when fwd's residual is a literal tuple of R elements, every tuple
    unpacking of bwd's residual parameter has exactly R targets;
  * bwd's literal tuple returns have primal_arity − N elements (one
    cotangent per differentiable primal argument).

Computed returns/unpacks (`return helper(...)`) are skipped, not guessed.
"""
from __future__ import annotations

import ast
from typing import Iterator, Optional

from repro.analysis import astutils
from repro.analysis.engine import ModuleInfo, RawFinding, Rule

_CUSTOM_VJP = ("jax.custom_vjp",)


def _literal_tuple_len(node: Optional[ast.AST]) -> Optional[int]:
    if isinstance(node, (ast.Tuple, ast.List)):
        return len(node.elts)
    return None


def _returns(func: ast.FunctionDef) -> list[ast.Return]:
    """Return statements belonging to `func` itself (not nested defs)."""
    out = []
    stack = list(func.body)
    while stack:
        node = stack.pop()
        if isinstance(node, astutils.FunctionLike):
            continue
        if isinstance(node, ast.Return):
            out.append(node)
        stack.extend(ast.iter_child_nodes(node))
    return out


class CustomVjpArityRule(Rule):
    id = "R005"
    name = "custom-vjp-arity"
    doc = __doc__

    def check(self, mod: ModuleInfo) -> Iterator[RawFinding]:
        funcs = {f.name: f for f in astutils.walk_functions(mod.tree)}
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "defvjp"
                    and isinstance(node.func.value, ast.Name)
                    and len(node.args) == 2):
                continue
            primal = funcs.get(node.func.value.id)
            if primal is None or not self._is_custom_vjp(primal, mod):
                continue
            fwd = (funcs.get(node.args[0].id)
                   if isinstance(node.args[0], ast.Name) else None)
            bwd = (funcs.get(node.args[1].id)
                   if isinstance(node.args[1], ast.Name) else None)
            yield from self._check_trio(mod, node, primal, fwd, bwd)

    def _is_custom_vjp(self, func: ast.FunctionDef, mod: ModuleInfo) -> bool:
        return any(qn in _CUSTOM_VJP
                   for qn, _ in astutils.decorator_info(func, mod.aliases))

    def _nondiff(self, func: ast.FunctionDef, mod: ModuleInfo
                 ) -> Optional[list[int]]:
        for qn, call in astutils.decorator_info(func, mod.aliases):
            if qn in _CUSTOM_VJP and call is not None:
                for kw in call.keywords:
                    if kw.arg == "nondiff_argnums":
                        dims = astutils.const_eval_dims(kw.value, {})
                        if dims is None or any(d is None for d in dims):
                            return None   # not statically resolvable
                        return dims
        return []

    def _check_trio(self, mod, defvjp_node, primal, fwd, bwd
                    ) -> Iterator[RawFinding]:
        idxs = self._nondiff(primal, mod)
        if idxs is None:
            return
        n_nondiff = len(idxs)
        if idxs != list(range(n_nondiff)):
            # non-leading nondiffs reorder jax's calling convention in ways
            # this rule doesn't model; demand the simple layout instead
            yield defvjp_node, (
                f"`{primal.name}` has non-leading nondiff_argnums {idxs}; "
                "use leading positions (0..N-1) so fwd/bwd arity is "
                "auditable")
            return
        a = primal.args
        if a.vararg or a.kwarg:
            return   # *args primals: arity not statically checkable
        n_primal = len(astutils.param_names(primal))

        if fwd is not None and not fwd.args.vararg:
            n_fwd = len(astutils.param_names(fwd))
            if n_fwd != n_primal:
                yield fwd, (
                    f"fwd `{fwd.name}` takes {n_fwd} parameter(s) but the "
                    f"primal `{primal.name}` takes {n_primal} — jax calls "
                    "fwd with exactly the primal arguments")
            for ret in _returns(fwd):
                rlen = _literal_tuple_len(ret.value)
                if rlen is not None and rlen != 2:
                    yield ret, (
                        f"fwd `{fwd.name}` must return `(out, residuals)`; "
                        f"this return has {rlen} element(s)")

        if bwd is not None and not bwd.args.vararg:
            n_bwd = len(astutils.param_names(bwd))
            want = n_nondiff + 2
            if n_bwd != want:
                yield bwd, (
                    f"bwd `{bwd.name}` takes {n_bwd} parameter(s), expected "
                    f"{want} ({n_nondiff} nondiff + residuals + cotangent) "
                    f"for `{primal.name}`")
            want_ct = n_primal - n_nondiff
            for ret in _returns(bwd):
                rlen = _literal_tuple_len(ret.value)
                if rlen is not None and rlen != want_ct:
                    yield ret, (
                        f"bwd `{bwd.name}` returns {rlen} cotangent(s), "
                        f"expected {want_ct} (one per differentiable "
                        f"argument of `{primal.name}`)")

        if fwd is not None and bwd is not None:
            yield from self._check_residuals(fwd, bwd, n_nondiff)

    def _check_residuals(self, fwd, bwd, n_nondiff) -> Iterator[RawFinding]:
        res_lens = set()
        for ret in _returns(fwd):
            if _literal_tuple_len(ret.value) == 2:
                rl = _literal_tuple_len(ret.value.elts[1])
                if rl is not None:
                    res_lens.add(rl)
        bwd_params = astutils.param_names(bwd)
        if len(res_lens) != 1 or len(bwd_params) < n_nondiff + 2:
            return
        res_len = res_lens.pop()
        res_name = bwd_params[n_nondiff]
        for node in ast.walk(bwd):
            if not isinstance(node, ast.Assign) or len(node.targets) != 1:
                continue
            tgt = node.targets[0]
            if (isinstance(tgt, (ast.Tuple, ast.List))
                    and isinstance(node.value, ast.Name)
                    and node.value.id == res_name):
                if len(tgt.elts) != res_len:
                    yield node, (
                        f"bwd `{bwd.name}` unpacks {len(tgt.elts)} "
                        f"residual(s) from `{res_name}` but fwd "
                        f"`{fwd.name}` saves {res_len} — the residual "
                        "tuple and this unpack drifted apart")
