"""Shared AST machinery for the rule modules.

Everything here is deliberately conservative: name resolution follows import
aliases only (no cross-module inference), and the constant evaluator returns
``None`` the moment an expression depends on a runtime value. Rules are
written so that "could not resolve" maps to either "skip" (R005 arity on a
computed return) or "flag" (R003 on a runtime-shaped VMEM block) depending on
which direction is safe for the invariant.
"""
from __future__ import annotations

import ast
from typing import Iterator, Optional


def build_parents(tree: ast.AST) -> dict[ast.AST, ast.AST]:
    """child -> parent map for the whole tree."""
    parents: dict[ast.AST, ast.AST] = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            parents[child] = node
    return parents


def import_aliases(tree: ast.AST) -> dict[str, str]:
    """Local name -> fully dotted origin, following `import x.y as z` and
    `from x.y import z [as w]`. `from . import z` resolves to just `z`."""
    aliases: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                aliases[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom):
            base = node.module or ""
            for a in node.names:
                if a.name == "*":
                    continue
                full = f"{base}.{a.name}" if base else a.name
                aliases[a.asname or a.name] = full
    return aliases


def qualname(node: ast.AST, aliases: dict[str, str]) -> Optional[str]:
    """Dotted name of a Name/Attribute chain with the root resolved through
    the import aliases: `jnp.concatenate` -> `jax.numpy.concatenate`."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    root = aliases.get(node.id, node.id)
    parts.append(root)
    return ".".join(reversed(parts))


def call_qualname(node: ast.AST, aliases: dict[str, str]) -> Optional[str]:
    if isinstance(node, ast.Call):
        return qualname(node.func, aliases)
    return None


def const_eval(node: ast.AST, env: dict[str, int]) -> Optional[int]:
    """Evaluate an int expression from literals + `env`; None if runtime."""
    if isinstance(node, ast.Constant):
        return node.value if isinstance(node.value, int) else None
    if isinstance(node, ast.Name):
        return env.get(node.id)
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        v = const_eval(node.operand, env)
        return None if v is None else -v
    if isinstance(node, ast.BinOp):
        lhs, rhs = const_eval(node.left, env), const_eval(node.right, env)
        if lhs is None or rhs is None:
            return None
        try:
            if isinstance(node.op, ast.Add):
                return lhs + rhs
            if isinstance(node.op, ast.Sub):
                return lhs - rhs
            if isinstance(node.op, ast.Mult):
                return lhs * rhs
            if isinstance(node.op, ast.FloorDiv):
                return lhs // rhs
            if isinstance(node.op, ast.Mod):
                return lhs % rhs
            if isinstance(node.op, ast.Pow):
                return lhs ** rhs
        except (ZeroDivisionError, OverflowError):
            return None
    return None


def const_eval_dims(node: ast.AST, env: dict[str, int]
                    ) -> Optional[list[Optional[int]]]:
    """A literal tuple/list of dim expressions -> per-dim ints (None where a
    dim is runtime-valued); None when the node is not a tuple/list at all."""
    if not isinstance(node, (ast.Tuple, ast.List)):
        return None
    return [const_eval(el, env) for el in node.elts]


def param_default_env(func: ast.FunctionDef) -> dict[str, int]:
    """Int-valued parameter defaults: the static block-shape knobs
    (`block_rows: int = 256`) that BlockSpec/scratch shapes are built from."""
    env: dict[str, int] = {}
    args = func.args
    pos = args.posonlyargs + args.args
    for arg, default in zip(pos[len(pos) - len(args.defaults):], args.defaults):
        if isinstance(default, ast.Constant) and isinstance(default.value, int):
            env[arg.arg] = default.value
    for arg, default in zip(args.kwonlyargs, args.kw_defaults):
        if (default is not None and isinstance(default, ast.Constant)
                and isinstance(default.value, int)):
            env[arg.arg] = default.value
    return env


def module_const_env(tree: ast.Module) -> dict[str, int]:
    """Top-level `NAME = <int literal>` assignments."""
    env: dict[str, int] = {}
    for node in tree.body:
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Constant):
            if isinstance(node.value.value, int):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        env[tgt.id] = node.value.value
    return env


FunctionLike = (ast.FunctionDef, ast.AsyncFunctionDef)


def walk_functions(tree: ast.AST) -> Iterator[ast.FunctionDef]:
    for node in ast.walk(tree):
        if isinstance(node, FunctionLike):
            yield node


def param_names(func: ast.FunctionDef) -> list[str]:
    a = func.args
    return [x.arg for x in a.posonlyargs + a.args + a.kwonlyargs]


def enclosing_functions(node: ast.AST, parents: dict[ast.AST, ast.AST]
                        ) -> list[ast.FunctionDef]:
    """Innermost-first chain of function defs containing `node`."""
    chain = []
    cur = parents.get(node)
    while cur is not None:
        if isinstance(cur, FunctionLike):
            chain.append(cur)
        cur = parents.get(cur)
    return chain


def decorator_info(func: ast.FunctionDef, aliases: dict[str, str]
                   ) -> list[tuple[str, Optional[ast.Call]]]:
    """(qualname, call-node-or-None) per decorator. For
    `functools.partial(jax.jit, ...)` the qualname reported is `jax.jit`'s
    and the call node is the partial call (whose keywords carry
    static_argnames / nondiff_argnums)."""
    out: list[tuple[str, Optional[ast.Call]]] = []
    for dec in func.decorator_list:
        if isinstance(dec, ast.Call):
            qn = qualname(dec.func, aliases)
            if qn == "functools.partial" and dec.args:
                inner = qualname(dec.args[0], aliases)
                if inner is not None:
                    out.append((inner, dec))
                    continue
            if qn is not None:
                out.append((qn, dec))
        else:
            qn = qualname(dec, aliases)
            if qn is not None:
                out.append((qn, None))
    return out


def str_elements(node: ast.AST) -> Optional[list[str]]:
    """A string literal or a tuple/list of them -> list of strings."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        vals = []
        for el in node.elts:
            if not (isinstance(el, ast.Constant) and isinstance(el.value, str)):
                return None
            vals.append(el.value)
        return vals
    return None
