"""repro.analysis — repo-specific static analysis for kernel/sharding invariants.

The LMC convergence guarantee (Thm. 2) holds only if the compensation path
computes exactly the gradients Eq. (9)/(12) prescribe, and in this repo those
semantics live in hand-written Pallas custom-VJP kernels guarded by
conventions a reviewer has to re-verify on every PR: concats must route
through ``concat_rows`` (the jax 0.4.37 sharded-concatenate miscompile,
DESIGN.md §4), streamed DMA kernels must pair every ``make_async_copy`` start
with a wait on the same semaphore, resident VMEM blocks must fit the ~12 MiB
Mosaic budget, and custom-VJP fwd/bwd signatures must agree on residual and
cotangent arity. This package turns those manual audits into machine-checked
rules (DESIGN.md §8):

  R001 sharded-concat   raw jnp.concatenate/stack outside dist/sharding.py
  R002 pallas-dma       unpaired/unconsumed async-copy starts and waits,
                        slot-count vs DMA-semaphore-shape mismatches
  R003 vmem-budget      statically estimated per-grid-step VMEM over budget,
                        statically unbounded (runtime-shaped) VMEM blocks
  R004 jit-hazards      host syncs + Python branches on traced values inside
                        jitted / custom-VJP / kernel bodies
  R005 custom-vjp-arity fwd residual tuple vs bwd unpack arity, fwd/bwd
                        parameter counts vs nondiff_argnums, bwd return arity
  R006 unbounded-queue  unbounded queue.Queue construction and blocking
                        get/put/join without timeout= in the threaded tiers
                        (src/repro/{data,serve} only)

Known-good exceptions are annotated in source with
``# lint: ok(R00x[,R00y]) <reason>`` pragmas — the reason is mandatory; a
reasonless pragma does not suppress and is itself reported (R000). The pass
runs self-hosted over ``src/`` as a tier-1 test (zero unsuppressed findings)
and as the first gate in ``scripts/check.sh``:

    python -m repro.analysis src/ [--rule R00x] [--json]
"""
from repro.analysis.engine import (Finding, Rule, all_rules, analyze_source,
                                   run_analysis, summarize)

__all__ = ["Finding", "Rule", "all_rules", "analyze_source", "run_analysis",
           "summarize"]
