"""R002/R003: the streamed-DMA and VMEM-residency invariants of the kernels.

R002 pallas-dma — the double-buffered HBM→VMEM gather protocol
(`kernels/ell_spmm.py`, `kernels/compensate.py`, DESIGN.md §3) only works if
every `pltpu.make_async_copy` start has a matching wait on the same
semaphore, slot indices alternate over exactly the semaphore count, and the
slot-major scratch buffer has one slot per semaphore. A missed wait reads
garbage into the accumulator *silently* on hardware (interpret mode emulates
the semaphores, so CPU CI catches only what it executes); a slot/semaphore
mismatch aliases in-flight copies. Three static checks:

  * every `make_async_copy` handle is consumed — `.start()`ed and `.wait()`ed
    directly (counts per semaphore expression must balance within a kernel),
    via a local name, or via the repo's helper idiom `op(make_async_copy(…))`
    where `op` is a parameter that module callers bind to *both* a
    `lambda dma: dma.start()` and a `lambda dma: dma.wait()`;
  * slot-major VMEM scratch (rank ≥ 3, literal slot dim) next to a
    `pltpu.SemaphoreType.DMA((n,))` must have exactly n slots;
  * literal moduli in `jax.lax.rem(_, c)` slot arithmetic inside DMA kernels
    must equal the semaphore count.

R003 vmem-budget — Mosaic rejects kernels whose per-grid-step residency
exceeds ~12 MiB of VMEM, and the failure surfaces at compile time on TPU
only: this CPU container's interpret mode happily runs any block size, which
is exactly how the pre-streaming resident-block cap (~24k gather rows) went
unnoticed until TPU lowering. The deleted trace-time guards are replaced
statically: BlockSpec block shapes and VMEM scratch shapes are evaluated from
literals + enclosing-function parameter defaults; a shape with a
runtime-valued dim (an operand row count) is an unbounded resident block and
must stream or carry a pragma, and resolvable shapes are summed per kernel
entry point against the 12 MiB budget.
"""
from __future__ import annotations

import ast
from typing import Iterator, Optional

from repro.analysis import astutils
from repro.analysis.engine import ModuleInfo, RawFinding, Rule

_MAC = "jax.experimental.pallas.tpu.make_async_copy"
_SEM_DMA = "jax.experimental.pallas.tpu.SemaphoreType.DMA"
_VMEM = "jax.experimental.pallas.tpu.VMEM"
_BLOCKSPEC_SUFFIX = ".BlockSpec"
_REM = "jax.lax.rem"

VMEM_BUDGET_BYTES = 12 * 2 ** 20     # Mosaic's practical per-step ceiling

_DTYPE_BYTES = {
    "float64": 8, "int64": 8, "uint64": 8,
    "float32": 4, "int32": 4, "uint32": 4,
    "bfloat16": 2, "float16": 2, "int16": 2, "uint16": 2,
    "int8": 1, "uint8": 1, "bool_": 1, "float8_e4m3fn": 1, "float8_e5m2": 1,
}


def _mac_calls(mod: ModuleInfo) -> list[ast.Call]:
    return [n for n in ast.walk(mod.tree)
            if astutils.call_qualname(n, mod.aliases) == _MAC]


def _outermost_function(node: ast.AST, mod: ModuleInfo
                        ) -> Optional[ast.FunctionDef]:
    chain = astutils.enclosing_functions(node, mod.parents)
    return chain[-1] if chain else None


def _lambda_dma_kind(node: ast.AST) -> Optional[str]:
    """`lambda dma: dma.start()` -> "start" (likewise wait); else None."""
    if isinstance(node, ast.Lambda) and isinstance(node.body, ast.Call):
        f = node.body.func
        if isinstance(f, ast.Attribute) and f.attr in ("start", "wait"):
            return f.attr
    return None


class DmaPairingRule(Rule):
    id = "R002"
    name = "pallas-dma"
    doc = __doc__

    def check(self, mod: ModuleInfo) -> Iterator[RawFinding]:
        macs = _mac_calls(mod)
        if macs:
            yield from self._check_consumption(mod, macs)
            yield from self._check_slots(mod, macs)

    # -- start/wait pairing ------------------------------------------------
    def _check_consumption(self, mod: ModuleInfo, macs: list[ast.Call]
                           ) -> Iterator[RawFinding]:
        # per kernel scope: sem-expression -> [(kind, node)] for direct uses
        direct: dict[ast.AST, dict[str, list[tuple[str, ast.Call]]]] = {}
        # DMA-applying helper params: (helper_def, param, index) -> mac node
        helpers: dict[tuple[ast.FunctionDef, str], ast.Call] = {}

        for mac in macs:
            scope = _outermost_function(mac, mod)
            parent = mod.parents.get(mac)
            grand = mod.parents.get(parent) if parent is not None else None
            # pltpu.make_async_copy(...).start() / .wait()
            if (isinstance(parent, ast.Attribute)
                    and parent.attr in ("start", "wait")
                    and isinstance(grand, ast.Call) and grand.func is parent):
                key = self._sem_key(mac)
                direct.setdefault(scope, {}).setdefault(key, []).append(
                    (parent.attr, mac))
                continue
            # op(pltpu.make_async_copy(...)) where `op` is an enclosing param
            if (isinstance(parent, ast.Call) and mac in parent.args
                    and isinstance(parent.func, ast.Name)):
                fname = parent.func.id
                encl = astutils.enclosing_functions(mac, mod.parents)
                owner = next((f for f in encl
                              if fname in astutils.param_names(f)), None)
                if owner is not None:
                    helpers[(owner, fname)] = mac
                    continue
                yield mac, (f"DMA handle passed to `{fname}`, which is not a "
                            "start/wait-applying parameter of an enclosing "
                            "function — cannot verify start/wait pairing")
                continue
            # dma = pltpu.make_async_copy(...); dma.start(); dma.wait()
            if (isinstance(parent, ast.Assign) and len(parent.targets) == 1
                    and isinstance(parent.targets[0], ast.Name)):
                dname = parent.targets[0].id
                kinds = self._name_consumption(scope or mod.tree, dname)
                if "start" not in kinds:
                    yield mac, (f"DMA handle `{dname}` is never `.start()`ed "
                                "in its kernel")
                if "wait" not in kinds:
                    yield mac, (f"DMA handle `{dname}` is `.start()`ed but "
                                "never `.wait()`ed — on hardware the compute "
                                "reads the scratch before the copy lands")
                continue
            yield mac, ("`make_async_copy` handle is neither started nor "
                        "waited (dropped on the floor)")

        for scope, by_sem in direct.items():
            for key, uses in by_sem.items():
                starts = [n for k, n in uses if k == "start"]
                waits = [n for k, n in uses if k == "wait"]
                if len(starts) > len(waits):
                    yield starts[len(waits)], (
                        "async copy started but never waited on semaphore "
                        f"`{key}` ({len(starts)} start(s) vs {len(waits)} "
                        "wait(s) in this kernel)")
                elif len(waits) > len(starts):
                    yield waits[len(starts)], (
                        "async copy waited but never started on semaphore "
                        f"`{key}` ({len(waits)} wait(s) vs {len(starts)} "
                        "start(s) in this kernel) — this wait deadlocks on "
                        "hardware")

        for (owner, pname), mac in helpers.items():
            kinds = self._helper_callers(mod, owner, pname)
            if kinds is None:
                yield owner, (f"DMA helper `{owner.name}` applies parameter "
                              f"`{pname}` to a `make_async_copy`, but no "
                              "caller passes a recognizable start/wait lambda")
            else:
                for missing in ("start", "wait") :
                    if missing not in kinds:
                        other = "wait" if missing == "start" else "start"
                        yield owner, (
                            f"DMA helper `{owner.name}` is only ever called "
                            f"with a `.{other}()` lambda for `{pname}` — "
                            f"every started copy needs a matching "
                            f"`.{missing}()` call")

    def _sem_key(self, mac: ast.Call) -> str:
        # make_async_copy(src, dst, sem): key on the semaphore expression so
        # starts and waits must balance per semaphore, not just per kernel
        if len(mac.args) >= 3:
            return ast.unparse(mac.args[2])
        return "<unknown-sem>"

    def _name_consumption(self, scope: ast.AST, name: str) -> set:
        kinds = set()
        for n in ast.walk(scope):
            if (isinstance(n, ast.Call) and isinstance(n.func, ast.Attribute)
                    and n.func.attr in ("start", "wait")
                    and isinstance(n.func.value, ast.Name)
                    and n.func.value.id == name):
                kinds.add(n.func.attr)
        return kinds

    def _helper_callers(self, mod: ModuleInfo, owner: ast.FunctionDef,
                        pname: str) -> Optional[set]:
        params = astutils.param_names(owner)
        pidx = params.index(pname)
        kinds: set = set()
        found = False
        for n in ast.walk(mod.tree):
            if (isinstance(n, ast.Call) and isinstance(n.func, ast.Name)
                    and n.func.id == owner.name):
                arg: Optional[ast.AST] = None
                if pidx < len(n.args):
                    arg = n.args[pidx]
                else:
                    arg = next((kw.value for kw in n.keywords
                                if kw.arg == pname), None)
                kind = _lambda_dma_kind(arg) if arg is not None else None
                if kind is not None:
                    found = True
                    kinds.add(kind)
        return kinds if found else None

    # -- slot-count / semaphore-shape consistency --------------------------
    def _check_slots(self, mod: ModuleInfo, macs: list[ast.Call]
                     ) -> Iterator[RawFinding]:
        sem_counts: list[tuple[ast.Call, int]] = []
        for n in ast.walk(mod.tree):
            if astutils.call_qualname(n, mod.aliases) == _SEM_DMA and n.args:
                dims = astutils.const_eval_dims(n.args[0], {})
                if dims and all(d is not None for d in dims):
                    count = 1
                    for d in dims:
                        count *= d
                    sem_counts.append((n, count))

        for sem_call, count in sem_counts:
            # slot-major scratch buffers declared alongside the semaphore
            # array (same scratch_shapes list) must have `count` slots
            parent = mod.parents.get(sem_call)
            if not isinstance(parent, (ast.List, ast.Tuple)):
                continue
            for sib in parent.elts:
                if astutils.call_qualname(sib, mod.aliases) != _VMEM:
                    continue
                if not sib.args or not isinstance(sib.args[0],
                                                  (ast.Tuple, ast.List)):
                    continue
                shape = sib.args[0].elts
                if len(shape) < 3:          # not slot-major double buffering
                    continue
                slots = astutils.const_eval(shape[0], {})
                if slots is not None and slots != count:
                    yield sib, (
                        f"slot-major VMEM scratch has {slots} slot(s) but "
                        f"the DMA semaphore array has {count} — in-flight "
                        "copies would share/miss semaphores")

        if len({c for _, c in sem_counts}) == 1 and sem_counts:
            count = sem_counts[0][1]
            scopes = {_outermost_function(mac, mod) for mac in macs}
            for func in scopes:
                if func is None:
                    continue
                for n in ast.walk(func):
                    if (astutils.call_qualname(n, mod.aliases) == _REM
                            and len(n.args) == 2):
                        c = astutils.const_eval(n.args[1], {})
                        if c is not None and c != count:
                            yield n, (
                                f"slot arithmetic `rem(_, {c})` does not "
                                f"alternate over the {count} DMA semaphore "
                                "slot(s)")


class VmemBudgetRule(Rule):
    id = "R003"
    name = "vmem-budget"
    doc = __doc__

    def check(self, mod: ModuleInfo) -> Iterator[RawFinding]:
        blocks = self._vmem_blocks(mod)
        per_func: dict[ast.FunctionDef, int] = {}
        oversized: set = set()
        for node, dims, nbytes, what in blocks:
            scope = _outermost_function(node, mod)
            if nbytes is None:
                missing = ", ".join(ast.unparse(e)
                                    for e, d in dims if d is None)
                yield node, (
                    f"{what} shape ({missing}, …) has runtime-valued dim(s): "
                    "the resident block is not statically bounded and Mosaic "
                    "rejects it past ~12 MiB at compile time (TPU-only — "
                    "interpret mode runs any size). Stream the operand "
                    "(`pltpu.ANY` + async-copy gather) or annotate with "
                    "`# lint: ok(R003) <static bound argument>`")
                continue
            if nbytes > VMEM_BUDGET_BYTES:
                oversized.add(scope)
                yield node, (
                    f"{what} is {nbytes / 2**20:.1f} MiB per grid step — "
                    f"over the ~{VMEM_BUDGET_BYTES // 2**20} MiB Mosaic VMEM "
                    "budget; shrink the block or stream it")
            if scope is not None:
                per_func[scope] = per_func.get(scope, 0) + nbytes
        for scope, total in per_func.items():
            if total > VMEM_BUDGET_BYTES and scope not in oversized:
                yield scope, (
                    f"statically resolvable VMEM blocks in `{scope.name}` "
                    f"sum to {total / 2**20:.1f} MiB per grid step — over "
                    f"the ~{VMEM_BUDGET_BYTES // 2**20} MiB Mosaic budget")

    def _vmem_blocks(self, mod: ModuleInfo):
        """Yield (node, [(dim_expr, val|None)], bytes|None, description) for
        every BlockSpec block shape and VMEM scratch shape in the module."""
        mod_env = astutils.module_const_env(mod.tree)
        out = []
        for node in ast.walk(mod.tree):
            qn = astutils.call_qualname(node, mod.aliases)
            if qn is None:
                continue
            is_blockspec = qn.endswith(_BLOCKSPEC_SUFFIX)
            is_vmem = qn == _VMEM
            if not (is_blockspec or is_vmem):
                continue
            if not node.args or not isinstance(node.args[0],
                                               (ast.Tuple, ast.List)):
                continue   # e.g. BlockSpec(memory_space=pltpu.ANY): HBM, fine
            env = dict(mod_env)
            for func in reversed(
                    astutils.enclosing_functions(node, mod.parents)):
                env.update(astutils.param_default_env(func))
            elts = node.args[0].elts
            dims = [(e, astutils.const_eval(e, env)) for e in elts]
            what = ("BlockSpec block" if is_blockspec else "VMEM scratch")
            if any(d is None for _, d in dims):
                out.append((node, dims, None, what))
                continue
            nbytes = self._dtype_bytes(node, is_vmem, mod)
            for _, d in dims:
                nbytes *= d
            out.append((node, dims, nbytes, what))
        return out

    def _dtype_bytes(self, node: ast.Call, is_vmem: bool,
                     mod: ModuleInfo) -> int:
        if is_vmem and len(node.args) >= 2:
            qn = astutils.qualname(node.args[1], mod.aliases)
            if qn is not None and qn.split(".")[-1] in _DTYPE_BYTES:
                return _DTYPE_BYTES[qn.split(".")[-1]]
        return 4   # unknown/operand-derived dtype: assume f32
