"""R004: host syncs and Python control flow on traced values in traced code.

The LMC train step, both custom-VJP pairs, and the kernel bodies are traced
exactly once and replayed; a `.item()` / `np.asarray(tracer)` inside them
forces a device sync per call (silently killing the async dispatch the
streamed kernels exist for), and a Python `if` on a traced value either
raises a ConcretizationTypeError at trace time or — worse — bakes one branch
into the compiled program for every input. This rule walks *traced scopes*:

  * functions decorated with `jax.jit` (directly or via
    `functools.partial(jax.jit, static_argnames=…)`),
  * the custom-VJP trio — `@jax.custom_vjp` primals and both functions
    registered through `X.defvjp(fwd, bwd)`,
  * Pallas kernel bodies — `functools.partial(<kernel_fn>, …)` targets in
    modules that call `pl.pallas_call`,

plus everything nested inside them, and flags `.item()`, `np.asarray` /
`np.array` / `jax.device_get` conversions, `float/int/bool(<param>)` casts,
and `if`/`while` tests referencing non-static parameters. Branches on
`static_argnames` parameters and `is None` pytree-structure checks are
trace-time constants and are exempt, as are `.shape`/`.ndim`/`.dtype`/`len()`
accesses (static under tracing).
"""
from __future__ import annotations

import ast
from typing import Iterator, Optional

from repro.analysis import astutils
from repro.analysis.engine import ModuleInfo, RawFinding, Rule

_JIT = ("jax.jit", "jax.pjit", "jax.experimental.pjit.pjit")
_CUSTOM_GRAD = ("jax.custom_vjp", "jax.custom_jvp")
_HOST_CONVERSIONS = ("numpy.asarray", "numpy.array", "jax.device_get")
_PALLAS_CALL = ("jax.experimental.pallas.pallas_call",)
_STATIC_ATTRS = ("shape", "ndim", "dtype", "size")
_CASTS = ("float", "int", "bool")


def _static_argnames(dec_call: Optional[ast.Call]) -> set:
    names: set = set()
    if dec_call is not None:
        for kw in dec_call.keywords:
            if kw.arg in ("static_argnames", "static_argnums"):
                vals = astutils.str_elements(kw.value)
                if vals:
                    names.update(vals)
    return names


def _nondiff_argnums(dec_call: Optional[ast.Call]) -> list[int]:
    if dec_call is not None:
        for kw in dec_call.keywords:
            if kw.arg == "nondiff_argnums":
                dims = astutils.const_eval_dims(kw.value, {})
                if dims and all(d is not None for d in dims):
                    return dims
    return []


def _params_at(func: ast.FunctionDef, idxs: list[int]) -> set:
    params = astutils.param_names(func)
    return {params[i] for i in idxs if 0 <= i < len(params)}


def _traced_roots(mod: ModuleInfo) -> dict[ast.FunctionDef, set]:
    """Traced top-of-scope functions -> their static parameter names."""
    roots: dict[ast.FunctionDef, set] = {}
    funcs = {f.name: f for f in astutils.walk_functions(mod.tree)}

    nondiff: dict[str, list[int]] = {}   # primal name -> nondiff positions
    for func in funcs.values():
        for qn, call in astutils.decorator_info(func, mod.aliases):
            if qn in _JIT:
                roots.setdefault(func, set()).update(_static_argnames(call))
            elif qn in _CUSTOM_GRAD:
                idxs = _nondiff_argnums(call)
                nondiff[func.name] = idxs
                roots.setdefault(func, set()).update(_params_at(func, idxs))

    # functions registered as fwd/bwd via X.defvjp(fwd, bwd): the primal's
    # nondiff positions are trace-time constants in fwd (same signature) and
    # arrive as the leading params of bwd
    has_pallas = False
    for node in ast.walk(mod.tree):
        qn = astutils.call_qualname(node, mod.aliases)
        if qn in _PALLAS_CALL:
            has_pallas = True
        if (isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute)
                and node.func.attr in ("defvjp", "defjvp")
                and isinstance(node.func.value, ast.Name)):
            idxs = nondiff.get(node.func.value.id, [])
            for k, arg in enumerate(node.args):
                if isinstance(arg, ast.Name) and arg.id in funcs:
                    f = funcs[arg.id]
                    pos = idxs if k == 0 else list(range(len(idxs)))
                    roots.setdefault(f, set()).update(_params_at(f, pos))

    # kernel bodies: functools.partial(<local fn>, ...) in a pallas module.
    # The partialed statics are keywords of the partial call itself, so the
    # kernel's own keyword-only params bound there are trace-time constants.
    if has_pallas:
        for node in ast.walk(mod.tree):
            if (astutils.call_qualname(node, mod.aliases) == "functools.partial"
                    and node.args and isinstance(node.args[0], ast.Name)
                    and node.args[0].id in funcs):
                kernel = funcs[node.args[0].id]
                statics = {kw.arg for kw in node.keywords if kw.arg}
                roots.setdefault(kernel, set()).update(statics)
    return roots


def _test_hazard_names(test: ast.AST, nonstatic: set) -> list[ast.Name]:
    """Non-static parameter Names the branch test actually traces.

    `x is None` / `x is not None` compares check pytree *structure* (static),
    and `.shape`/`.ndim`/`.dtype`/`len()` are static under tracing — names
    used only that way are exempt.
    """
    if isinstance(test, ast.Compare) and all(
            isinstance(op, (ast.Is, ast.IsNot)) for op in test.ops):
        return []
    exempt: set = set()
    for n in ast.walk(test):
        if isinstance(n, ast.Attribute) and n.attr in _STATIC_ATTRS:
            for sub in ast.walk(n.value):
                exempt.add(id(sub))
        if (isinstance(n, ast.Call) and isinstance(n.func, ast.Name)
                and n.func.id in ("len", "isinstance")):
            for arg in n.args:
                for sub in ast.walk(arg):
                    exempt.add(id(sub))
    return [n for n in ast.walk(test)
            if isinstance(n, ast.Name) and n.id in nonstatic
            and id(n) not in exempt]


class JitHazardRule(Rule):
    id = "R004"
    name = "jit-hazards"
    doc = __doc__

    def check(self, mod: ModuleInfo) -> Iterator[RawFinding]:
        for root, statics in _traced_roots(mod).items():
            nonstatic = {p for p in astutils.param_names(root)
                         if p not in statics}
            # nested defs: their own params are local trace values too,
            # minus names that shadow a static (partial-bound) one
            for func in [root, *[f for f in astutils.walk_functions(root)
                                 if f is not root]]:
                if func is not root:
                    nonstatic |= {p for p in astutils.param_names(func)
                                  if p not in statics}
            yield from self._check_scope(mod, root, nonstatic)

    def _check_scope(self, mod: ModuleInfo, root: ast.FunctionDef,
                     nonstatic: set) -> Iterator[RawFinding]:
        for node in ast.walk(root):
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "item" and not node.args):
                yield node, ("`.item()` inside a traced scope forces a "
                             "host sync per call (or fails to trace) — "
                             "keep the value on device or hoist it out of "
                             f"`{root.name}`")
                continue
            qn = astutils.call_qualname(node, mod.aliases)
            if qn in _HOST_CONVERSIONS:
                yield node, (f"`{qn}` inside traced `{root.name}` pulls the "
                             "array to host memory — use jnp, or move the "
                             "conversion outside the jitted scope")
                continue
            if (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
                    and node.func.id in _CASTS and len(node.args) == 1
                    and isinstance(node.args[0], ast.Name)
                    and node.args[0].id in nonstatic):
                yield node, (f"`{node.func.id}({node.args[0].id})` on a "
                             f"traced parameter of `{root.name}` "
                             "concretizes the tracer (host sync / trace "
                             "error)")
                continue
            if isinstance(node, (ast.If, ast.While)):
                hazards = _test_hazard_names(node.test, nonstatic)
                if hazards:
                    names = ", ".join(sorted({n.id for n in hazards}))
                    kind = "if" if isinstance(node, ast.If) else "while"
                    yield node, (
                        f"Python `{kind}` on traced value(s) `{names}` "
                        f"inside `{root.name}` — tracing bakes in one "
                        "branch (or raises ConcretizationTypeError); use "
                        "`jnp.where`/`lax.cond`, or mark the argument "
                        "static")
