"""Pallas TPU kernels for the paper's compute hot-spots.

  ell_spmm.py    — blocked-ELL SpMM (the GNN aggregation the paper's CUDA
                   backend implements with scatter/gather); vectorized tile
                   kernel with scalar-prefetched index tiles; ref:
                   ref.ell_spmm_ref
  compensate.py  — fused gather + convex-combination for LMC Eq. (9)/(12)
  ops.py         — differentiable jit wrappers: degree-bucketed production
                   SpMM + compensate with custom VJPs (transpose-graph
                   backward), bulk-numpy ELL builders, AggregateFn
  ref.py         — pure-jnp oracles

Kernels are written for TPU (pl.pallas_call + PrefetchScalarGridSpec VMEM
tiling, 8x128 aligned). ``interpret`` autodetects per backend: compiled Mosaic
on TPU, interpreter fallback on CPU containers. ``stream`` autodetects to the
HBM→VMEM double-buffered DMA gather (per-row ``pltpu.make_async_copy`` into a
2-slot VMEM scratch), which removes any VMEM bound on the gather source —
full-graph historical stores compile (DESIGN.md §3).
"""
from repro.kernels.ops import (ELLCapacityError, ELLGraph, build_ell,
                               bucketed_spmm, default_interpret,
                               default_stream, ell_aggregate_fn, ell_from_coo,
                               ell_spmm, fixed_row_capacity, lmc_compensate)
from repro.kernels import ref

__all__ = ["ELLCapacityError", "ELLGraph", "build_ell", "ell_from_coo",
           "fixed_row_capacity", "bucketed_spmm", "ell_spmm", "lmc_compensate",
           "ell_aggregate_fn", "default_interpret", "default_stream", "ref"]
