"""Pallas TPU kernels for the paper's compute hot-spots.

  ell_spmm.py    — blocked-ELL SpMM (the GNN aggregation the paper's CUDA
                   backend implements with scatter/gather); ref: ref.ell_spmm_ref
  compensate.py  — fused gather + convex-combination for LMC Eq. (9)/(12)
  ops.py         — jit wrappers: degree-bucketed production SpMM, AggregateFn
  ref.py         — pure-jnp oracles

Kernels are written for TPU (pl.pallas_call + BlockSpec VMEM tiling, 8x128
aligned) and validated here in interpret mode (CPU container).
"""
from repro.kernels.ops import (ELLGraph, build_ell, bucketed_spmm, ell_spmm,
                               lmc_compensate, ell_aggregate_fn)
from repro.kernels import ref

__all__ = ["ELLGraph", "build_ell", "bucketed_spmm", "ell_spmm",
           "lmc_compensate", "ell_aggregate_fn", "ref"]
