"""Blocked-ELL SpMM — the paper's aggregation hot-spot as a Pallas TPU kernel.

TPU adaptation of the CUDA scatter/gather SpMM the paper's PyG backend uses
(DESIGN.md §3): neighbor lists are padded to a per-bucket width K (powers of
two, host-side degree bucketing bounds the padding waste), giving a dense
(N, K) index/weight layout whose row tiles stream through VMEM; features are
blocked along D so a (block_rows, block_d) output tile accumulates K gathered
neighbor planes at a time.

Kernel layout (vectorized — no per-row scalar accumulation):
  * the neighbor-index array rides in as a *scalar-prefetch* operand
    (``pltpu.PrefetchScalarGridSpec``), so row indices are resolved from SMEM
    before the VMEM gathers they drive;
  * for each k < K the kernel copies the k-th neighbor row of every row in the
    tile into a (block_rows, block_d) VMEM scratch via dynamic slices, then
    accumulates ``w[:, k:k+1] * gathered`` as one broadcast multiply-add over
    the whole tile — the VPU lanes stay full instead of reducing one (D,)
    vector per row at a time.

``interpret=None`` autodetects the backend: compiled Mosaic on TPU,
interpreter fallback elsewhere (CPU containers cannot lower Mosaic kernels).
All tile dims are multiples of (8, 128) for VREG/MXU layout.

VMEM budget per grid step (defaults): h block (M≤8192, 128) f32 = 4 MiB,
w tile (256, K≤128) = 128 KiB, out tile + gather scratch (256, 128) ×2 =
256 KiB; the full (N, K≤128) int32 index array lives in SMEM (scalar
prefetch), which bounds practical N·K for the compiled path — the bucketed
wrapper (ops.py) keeps per-call index arrays at mini-batch scale.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def default_interpret() -> bool:
    """True when the Pallas kernels should run interpreted (no TPU present)."""
    return jax.default_backend() != "tpu"


def _spmm_kernel(idx_ref, w_ref, h_ref, o_ref, gath_ref, acc_ref, *, K: int,
                 block_rows: int):
    """One (row-tile × feature-tile) step: gather-accumulate K neighbors.

    idx_ref: full (N, K) int32 in SMEM (scalar prefetch); w_ref: (bn, K) VMEM
    tile; h_ref: (M, bd) VMEM feature block; gath_ref: (bn, bd) VMEM scratch;
    acc_ref: (bn, bd) f32 accumulator (full precision even for bf16 inputs).
    """
    row0 = pl.program_id(0) * block_rows
    acc_ref[:] = jnp.zeros_like(acc_ref)

    def k_step(k, _):
        def gather_row(r, _):
            j = idx_ref[row0 + r, k]
            gath_ref[pl.ds(r, 1), :] = h_ref[pl.ds(j, 1), :]
            return 0

        jax.lax.fori_loop(0, block_rows, gather_row, 0)
        acc_ref[:] += (w_ref[:, pl.ds(k, 1)].astype(jnp.float32)
                       * gath_ref[:].astype(jnp.float32))
        return 0

    jax.lax.fori_loop(0, K, k_step, 0)
    o_ref[:] = acc_ref[:].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "block_d",
                                             "interpret"))
def ell_spmm(nbr_idx: jax.Array, nbr_w: jax.Array, h: jax.Array, *,
             block_rows: int = 256, block_d: int = 128,
             interpret: bool | None = None) -> jax.Array:
    """out[i] = Σ_k w[i,k] · h[idx[i,k]]  via pl.pallas_call.

    nbr_idx/nbr_w: (N, K); h: (M, D). N must divide by block_rows and D by
    block_d (the ops.py wrapper pads). ``interpret=None`` autodetects:
    compiled on TPU, interpreted elsewhere.
    """
    if interpret is None:
        interpret = default_interpret()
    n, k = nbr_idx.shape
    m, d = h.shape
    assert n % block_rows == 0 and d % block_d == 0, (n, d)
    if not interpret and m * block_d * h.dtype.itemsize > 12 * 2**20:
        raise ValueError(
            f"ell_spmm: feature block ({m}, {block_d}) "
            f"{m * block_d * h.dtype.itemsize / 2**20:.0f} MiB exceeds the "
            "compiled-path VMEM budget (12 MiB) — mini-batch-scale gather "
            "sources only until HBM-DMA streaming lands (ROADMAP)")
    grid = (n // block_rows, d // block_d)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,  # nbr_idx -> SMEM, readable before DMA
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, k), lambda i, j, idx: (i, 0)),
            pl.BlockSpec((m, block_d), lambda i, j, idx: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_rows, block_d), lambda i, j, idx: (i, j)),
        scratch_shapes=[pltpu.VMEM((block_rows, block_d), h.dtype),
                        pltpu.VMEM((block_rows, block_d), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_spmm_kernel, K=k, block_rows=block_rows),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, d), h.dtype),
        interpret=interpret,
    )(nbr_idx, nbr_w, h)
