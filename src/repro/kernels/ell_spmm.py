"""Blocked-ELL SpMM — the paper's aggregation hot-spot as a Pallas TPU kernel.

TPU adaptation of the CUDA scatter/gather SpMM the paper's PyG backend uses
(DESIGN.md §3): neighbor lists are padded to a per-bucket width K (powers of
two, host-side degree bucketing bounds the padding waste), giving a dense
(N, K) index/weight layout whose row tiles stream through VMEM; features are
blocked along D so a (block_rows, block_d) output tile accumulates K gathered
neighbor planes at a time.

Two gather strategies share the accumulation layout (vectorized — no per-row
scalar accumulation; broadcast multiply-add over the whole tile, f32
accumulator, full VPU lanes):

  * ``stream=True`` (default): the feature operand stays in **HBM**
    (``pltpu.ANY`` memory space) and the kernel body issues per-row
    HBM→VMEM ``pltpu.make_async_copy`` gathers, driven by the
    scalar-prefetched SMEM indices, into a 2-slot ``(block_rows, block_d)``
    VMEM scratch. Neighbor plane k+1's copies start before plane k's wait, so
    the DMA for k+1 overlaps the multiply-add for k (double buffering). No
    VMEM bound on the gather source M — this is what lets the compiled path
    gather from full-graph stores (the old resident block capped M at ~24k
    f32 rows/device).
  * ``stream=False``: the legacy resident block — the whole ``(M, block_d)``
    feature slab rides in as one VMEM block and rows are copied out of it with
    dynamic slices. Cheaper for small sources revisited by many rows (one
    block load per feature tile instead of N·K row DMAs) but bounded by VMEM:
    forcing it with a source past ~12 MiB per block fails at Mosaic compile
    time on TPU.

``interpret=None`` / ``stream=None`` autodetect: compiled Mosaic on TPU,
interpreter fallback elsewhere (CPU containers cannot lower Mosaic kernels);
streaming everywhere (the interpreter emulates the DMA/semaphore protocol
exactly, so CPU CI verifies the streamed path — including at M well past the
old cap). All tile dims are multiples of (8, 128) for VREG/MXU layout.

VMEM budget per grid step (defaults, streamed): 2-slot gather scratch
(2, 256, 128) f32 = 256 KiB, w tile (256, K≤128) = 128 KiB, out tile + f32
accumulator (256, 128) ×2 = 256 KiB — independent of M. The full (N, K≤128)
int32 index array lives in SMEM (scalar prefetch), which bounds practical N·K
for the compiled path — the bucketed wrapper (ops.py) keeps per-call index
arrays at mini-batch scale.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def default_interpret() -> bool:
    """True when the Pallas kernels should run interpreted (no TPU present)."""
    return jax.default_backend() != "tpu"


def default_stream() -> bool:
    """True when the gather source should stream HBM→VMEM via per-row DMA.

    Streaming is the production default on every backend: it removes the
    resident-block VMEM cap on the gather source (full-graph historical
    stores compile), and the interpreter emulates the DMA protocol exactly so
    the same path is what CPU CI verifies. ``stream=False`` keeps the
    resident-block kernel for small sources and for streamed-vs-resident
    benchmarking.
    """
    return True


def _spmm_resident_kernel(idx_ref, w_ref, h_ref, o_ref, gath_ref, acc_ref, *,
                          K: int, block_rows: int):
    """Resident-block body: gather rows out of a full (M, block_d) VMEM slab.

    idx_ref: full (N, K) int32 in SMEM (scalar prefetch); w_ref: (bn, K) VMEM
    tile; h_ref: (M, bd) VMEM feature block; gath_ref: (bn, bd) VMEM scratch;
    acc_ref: (bn, bd) f32 accumulator (full precision even for bf16 inputs).
    """
    row0 = pl.program_id(0) * block_rows
    acc_ref[:] = jnp.zeros_like(acc_ref)

    def k_step(k, _):
        def gather_row(r, _):
            j = idx_ref[row0 + r, k]
            gath_ref[pl.ds(r, 1), :] = h_ref[pl.ds(j, 1), :]
            return 0

        jax.lax.fori_loop(0, block_rows, gather_row, 0)
        acc_ref[:] += (w_ref[:, pl.ds(k, 1)].astype(jnp.float32)
                       * gath_ref[:].astype(jnp.float32))
        return 0

    jax.lax.fori_loop(0, K, k_step, 0)
    o_ref[:] = acc_ref[:].astype(o_ref.dtype)


def _spmm_stream_kernel(idx_ref, w_ref, h_ref, o_ref, gath_ref, acc_ref,
                        sem_ref, *, K: int, block_rows: int, block_d: int):
    """Streaming body: per-row HBM→VMEM DMA gathers, double-buffered over k.

    h_ref lives in HBM (``pltpu.ANY``); gath_ref is a (2, bn, bd) VMEM
    double buffer; sem_ref a (2,) DMA-semaphore array, one per slot. Neighbor
    plane k lands in slot k % 2: its copies are started one plane ahead
    (while plane k-1's multiply-add runs) and waited right before use. Every
    started copy is waited in the same grid step, so no DMA crosses grid-step
    boundaries.
    """
    row0 = pl.program_id(0) * block_rows
    col0 = pl.program_id(1) * block_d

    def plane(k, slot, op):
        """start()/wait() the bn row-copies of neighbor plane k into slot."""
        def row(r, _):
            j = idx_ref[row0 + r, k]
            op(pltpu.make_async_copy(
                h_ref.at[pl.ds(j, 1), pl.ds(col0, block_d)],
                gath_ref.at[slot, pl.ds(r, 1), :],
                sem_ref.at[slot]))
            return 0

        jax.lax.fori_loop(0, block_rows, row, 0)

    acc_ref[:] = jnp.zeros_like(acc_ref)
    plane(0, 0, lambda dma: dma.start())

    def k_step(k, _):
        slot = jax.lax.rem(k, 2)

        @pl.when(k + 1 < K)
        def _():  # overlap: plane k+1's DMA flies during plane k's compute
            plane(k + 1, jax.lax.rem(k + 1, 2), lambda dma: dma.start())

        plane(k, slot, lambda dma: dma.wait())
        acc_ref[:] += (w_ref[:, pl.ds(k, 1)].astype(jnp.float32)
                       * gath_ref[slot].astype(jnp.float32))
        return 0

    jax.lax.fori_loop(0, K, k_step, 0)
    o_ref[:] = acc_ref[:].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "block_d",
                                             "interpret", "stream"))
def ell_spmm(nbr_idx: jax.Array, nbr_w: jax.Array, h: jax.Array, *,
             block_rows: int = 256, block_d: int = 128,
             interpret: bool | None = None,
             stream: bool | None = None) -> jax.Array:
    """out[i] = Σ_k w[i,k] · h[idx[i,k]]  via pl.pallas_call.

    nbr_idx/nbr_w: (N, K); h: (M, D). N must divide by block_rows and D by
    block_d (the ops.py wrapper pads). ``interpret=None`` autodetects:
    compiled on TPU, interpreted elsewhere. ``stream=None`` autodetects to
    the HBM→VMEM DMA gather (no VMEM bound on M); ``stream=False`` forces the
    legacy resident ``(M, block_d)`` VMEM block (small sources only).
    """
    if interpret is None:
        interpret = default_interpret()
    if stream is None:
        stream = default_stream()
    n, k = nbr_idx.shape
    m, d = h.shape
    assert n % block_rows == 0 and d % block_d == 0, (n, d)
    grid = (n // block_rows, d // block_d)
    if stream:
        kernel = functools.partial(_spmm_stream_kernel, K=k,
                                   block_rows=block_rows, block_d=block_d)
        h_spec = pl.BlockSpec(memory_space=pltpu.ANY)  # stays in HBM
        scratch = [pltpu.VMEM((2, block_rows, block_d), h.dtype),
                   pltpu.VMEM((block_rows, block_d), jnp.float32),
                   pltpu.SemaphoreType.DMA((2,))]
    else:
        kernel = functools.partial(_spmm_resident_kernel, K=k,
                                   block_rows=block_rows)
        # lint: ok(R003) legacy resident path: stream=True is the default and
        # Mosaic rejects >12 MiB blocks at compile time; kept for small
        # sources + streamed-vs-resident benchmarking (module docstring)
        h_spec = pl.BlockSpec((m, block_d), lambda i, j, idx: (0, j))
        scratch = [pltpu.VMEM((block_rows, block_d), h.dtype),
                   pltpu.VMEM((block_rows, block_d), jnp.float32)]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,  # nbr_idx -> SMEM, readable before DMA
        grid=grid,
        in_specs=[
            # lint: ok(R003) K <= 128 by bucket construction: build_ell caps
            # bucket widths at powers of two <= 128, so this w tile is at
            # most (256, 128) f32 = 128 KiB
            pl.BlockSpec((block_rows, k), lambda i, j, idx: (i, 0)),
            h_spec,
        ],
        out_specs=pl.BlockSpec((block_rows, block_d), lambda i, j, idx: (i, j)),
        scratch_shapes=scratch,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, d), h.dtype),
        interpret=interpret,
    )(nbr_idx, nbr_w, h)
