"""Blocked-ELL SpMM — the paper's aggregation hot-spot as a Pallas TPU kernel.

TPU adaptation of the CUDA scatter/gather SpMM the paper's PyG backend uses
(DESIGN.md §3): neighbor lists are padded to a per-bucket width K (powers of
two, host-side degree bucketing bounds the padding waste), giving a dense
(N, K) index/weight layout whose row tiles stream through VMEM; features are
blocked along D so a (rows_block, D_block) output tile accumulates K gathered
rows at a time. All tile dims are multiples of (8, 128) for VREG/MXU layout.

VMEM budget per grid step (defaults): h block (M≤8192, 128) f32 = 4 MiB,
idx/w tiles (256, K≤128) = 256 KiB, out tile (256, 128) = 128 KiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spmm_kernel(idx_ref, w_ref, h_ref, o_ref, *, K: int):
    """One (row-tile × feature-tile) step: gather-accumulate K neighbors."""
    bn = o_ref.shape[0]
    bd = o_ref.shape[1]

    def row_body(i, _):
        def k_body(k, acc):
            j = idx_ref[i, k]
            vec = pl.load(h_ref, (pl.dslice(j, 1), slice(None)))   # (1, BD)
            return acc + w_ref[i, k] * vec[0]

        acc = jax.lax.fori_loop(0, K, k_body,
                                jnp.zeros((bd,), o_ref.dtype))
        pl.store(o_ref, (pl.dslice(i, 1), slice(None)), acc[None])
        return 0

    jax.lax.fori_loop(0, bn, row_body, 0)


@functools.partial(jax.jit, static_argnames=("block_rows", "block_d",
                                             "interpret"))
def ell_spmm(nbr_idx: jax.Array, nbr_w: jax.Array, h: jax.Array, *,
             block_rows: int = 256, block_d: int = 128,
             interpret: bool = True) -> jax.Array:
    """out[i] = Σ_k w[i,k] · h[idx[i,k]]  via pl.pallas_call.

    nbr_idx/nbr_w: (N, K); h: (M, D). N must divide by block_rows and D by
    block_d (the ops.py wrapper pads). ``interpret=True`` executes the kernel
    body in Python on CPU (this container has no TPU).
    """
    n, k = nbr_idx.shape
    m, d = h.shape
    assert n % block_rows == 0 and d % block_d == 0, (n, d)
    grid = (n // block_rows, d // block_d)
    return pl.pallas_call(
        functools.partial(_spmm_kernel, K=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_rows, k), lambda i, j: (i, 0)),
            pl.BlockSpec((m, block_d), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_rows, block_d), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, d), h.dtype),
        interpret=interpret,
    )(nbr_idx, nbr_w, h)
