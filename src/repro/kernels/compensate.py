"""Fused LMC compensation kernel — Eq. (9)/(12)'s gather + convex-combine.

The per-halo-node update  ĥ_i = m_i·[(1-β_i)·H̄[gid_i] + β_i·h̃_i]  is a gather
from the (node-sharded) historical store fused with the lerp and validity
mask, so the historical row never round-trips through HBM twice.

Kernel layout mirrors ell_spmm.py: the gather ids ride in as a scalar-prefetch
operand (SMEM), a row loop copies the gathered store rows into a
(block_rows, block_d) VMEM scratch, and the lerp+mask runs as one broadcast
multiply-add over the whole tile (β and mask arrive as (N, 1) lane-broadcast
columns). ``interpret=None`` autodetects compiled-vs-interpreted like
ell_spmm. This module exposes the shape-aligned raw kernel call; the padded,
differentiable production entry point is ``ops.lmc_compensate``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ell_spmm import default_interpret


def _comp_kernel(gid_ref, beta_ref, mask_ref, fresh_ref, store_ref, o_ref,
                 gath_ref, *, block_rows: int):
    row0 = pl.program_id(0) * block_rows

    def gather_row(r, _):
        g = gid_ref[row0 + r]
        gath_ref[pl.ds(r, 1), :] = store_ref[pl.ds(g, 1), :]
        return 0

    jax.lax.fori_loop(0, block_rows, gather_row, 0)
    b = beta_ref[:]          # (bn, 1) broadcast over lanes
    o_ref[:] = mask_ref[:] * ((1.0 - b) * gath_ref[:] + b * fresh_ref[:])


@functools.partial(jax.jit, static_argnames=("block_rows", "block_d",
                                             "interpret"))
def lmc_compensate_kernel(store: jax.Array, gids: jax.Array, beta: jax.Array,
                          fresh: jax.Array, mask: jax.Array, *,
                          block_rows: int = 256, block_d: int = 128,
                          interpret: bool | None = None) -> jax.Array:
    """store (M, D); gids/beta/mask (N,); fresh (N, D) -> (N, D).

    Requires N % block_rows == 0 and D % block_d == 0 (``ops.lmc_compensate``
    pads and adds the custom VJP).
    """
    if interpret is None:
        interpret = default_interpret()
    n, d = fresh.shape
    m = store.shape[0]
    assert n % block_rows == 0 and d % block_d == 0, (n, d)
    if not interpret and m * block_d * store.dtype.itemsize > 12 * 2**20:
        # the gather source rides as one (M, block_d) VMEM block: full-graph
        # stores blow VMEM on the compiled path until HBM-DMA row streaming
        # lands (ROADMAP). Shard/partition the store, or stay interpreted.
        raise ValueError(
            f"lmc_compensate: store block ({m}, {block_d}) "
            f"{m * block_d * store.dtype.itemsize / 2**20:.0f} MiB exceeds "
            "the compiled-path VMEM budget (12 MiB); see ROADMAP (HBM-DMA "
            "store streaming)")
    grid = (n // block_rows, d // block_d)
    beta2 = beta.reshape(n, 1).astype(fresh.dtype)
    mask2 = mask.reshape(n, 1).astype(fresh.dtype)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,  # gids -> SMEM
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, 1), lambda i, j, gid: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i, j, gid: (i, 0)),
            pl.BlockSpec((block_rows, block_d), lambda i, j, gid: (i, j)),
            pl.BlockSpec((m, block_d), lambda i, j, gid: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_rows, block_d), lambda i, j, gid: (i, j)),
        scratch_shapes=[pltpu.VMEM((block_rows, block_d), fresh.dtype)],
    )
    return pl.pallas_call(
        functools.partial(_comp_kernel, block_rows=block_rows),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, d), fresh.dtype),
        interpret=interpret,
    )(gids, beta2, mask2, fresh, store)
