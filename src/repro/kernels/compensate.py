"""Fused LMC compensation kernel — Eq. (9)/(12)'s gather + convex-combine.

The per-halo-node update  ĥ_i = m_i·[(1-β_i)·H̄[gid_i] + β_i·h̃_i]  is a gather
from the (node-sharded) historical store fused with the lerp and validity
mask, so the historical row never round-trips through HBM twice.

Kernel layout mirrors ell_spmm.py: the gather ids ride in as a scalar-prefetch
operand (SMEM) and the lerp+mask runs as one broadcast multiply-add over the
whole tile (β and mask arrive as (N, 1) lane-broadcast columns). The gather
itself has two strategies:

  * ``stream=True`` (default): the store stays in **HBM** (``pltpu.ANY``) and
    each grid step's (block_rows, block_d) gather arrives via per-row
    HBM→VMEM ``pltpu.make_async_copy`` into a 2-slot VMEM scratch. The
    pipeline runs across grid steps: step t's compute overlaps step t+1's
    DMA (slot t % 2 computes while slot (t+1) % 2 fills). The store is
    *full-graph* on the LMC train path, so this is the path that makes
    ``backend="ell"`` compile at paper scale — the old resident block capped
    the store at ~24k f32 rows/device.
  * ``stream=False``: legacy resident ``(M, block_d)`` VMEM store block
    (small stores only; past ~12 MiB per block Mosaic fails at compile time).

``interpret=None`` / ``stream=None`` autodetect like ell_spmm (the
interpreter emulates the DMA/semaphore protocol exactly, so CPU CI verifies
the streamed path at M well past the old cap). This module exposes the
shape-aligned raw kernel call; the padded, differentiable production entry
point is ``ops.lmc_compensate``.
"""
from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ell_spmm import default_interpret, default_stream


def _comp_resident_kernel(gid_ref, beta_ref, mask_ref, fresh_ref, store_ref,
                          o_ref, gath_ref, *, block_rows: int):
    row0 = pl.program_id(0) * block_rows

    def gather_row(r, _):
        g = gid_ref[row0 + r]
        gath_ref[pl.ds(r, 1), :] = store_ref[pl.ds(g, 1), :]
        return 0

    jax.lax.fori_loop(0, block_rows, gather_row, 0)
    b = beta_ref[:]          # (bn, 1) broadcast over lanes
    o_ref[:] = mask_ref[:] * ((1.0 - b) * gath_ref[:] + b * fresh_ref[:])


def _comp_stream_kernel(gid_ref, beta_ref, mask_ref, fresh_ref, store_ref,
                        o_ref, gath_ref, sem_ref, *, block_rows: int,
                        block_d: int, grid_j: int):
    """Streaming body, pipelined across row/feature tiles.

    store_ref lives in HBM (``pltpu.ANY``); gath_ref is a (2, bn, bd) VMEM
    double buffer; sem_ref a (2,) DMA-semaphore array. Grid steps run
    sequentially on a TPU core and scratch persists across them, so tile
    t = i·J + j computes out of slot t % 2 while tile t+1's row copies fill
    slot (t+1) % 2 — the gather DMA for the next tile overlaps this tile's
    lerp. Tile 0 pays the only un-overlapped gather (warm-up).
    """
    t = pl.program_id(0) * grid_j + pl.program_id(1)
    num_t = pl.num_programs(0) * grid_j

    def tile(t_, slot, op):
        """start()/wait() the bn row-copies of grid tile t_ into slot."""
        i = jax.lax.div(t_, grid_j)
        col0 = jax.lax.rem(t_, grid_j) * block_d

        def row(r, _):
            g = gid_ref[i * block_rows + r]
            op(pltpu.make_async_copy(
                store_ref.at[pl.ds(g, 1), pl.ds(col0, block_d)],
                gath_ref.at[slot, pl.ds(r, 1), :],
                sem_ref.at[slot]))
            return 0

        jax.lax.fori_loop(0, block_rows, row, 0)

    @pl.when(t == 0)
    def _():  # warm-up: the first tile's gather cannot overlap anything
        tile(0, 0, lambda dma: dma.start())

    @pl.when(t + 1 < num_t)
    def _():  # overlap: next tile's DMA flies during this tile's lerp
        tile(t + 1, jax.lax.rem(t + 1, 2), lambda dma: dma.start())

    slot = jax.lax.rem(t, 2)
    tile(t, slot, lambda dma: dma.wait())
    b = beta_ref[:]          # (bn, 1) broadcast over lanes
    hist = gath_ref[slot].astype(fresh_ref.dtype)
    o_ref[:] = mask_ref[:] * ((1.0 - b) * hist + b * fresh_ref[:])


@functools.partial(jax.jit, static_argnames=("block_rows", "block_d",
                                             "interpret", "stream"))
def lmc_compensate_kernel(store: jax.Array, gids: jax.Array, beta: jax.Array,
                          fresh: jax.Array, mask: jax.Array, *,
                          block_rows: int = 256, block_d: int = 128,
                          interpret: bool | None = None,
                          stream: bool | None = None) -> jax.Array:
    """store (M, D); gids/beta/mask (N,); fresh (N, D) -> (N, D).

    Requires N % block_rows == 0 and D % block_d == 0 (``ops.lmc_compensate``
    pads and adds the custom VJP). ``stream=None`` autodetects to the
    HBM→VMEM DMA gather — no VMEM bound on the store row count M;
    ``stream=False`` forces the legacy resident store block (small M only).
    """
    if interpret is None:
        interpret = default_interpret()
    if stream is None:
        stream = default_stream()
    n, d = fresh.shape
    m = store.shape[0]
    assert n % block_rows == 0 and d % block_d == 0, (n, d)
    grid = (n // block_rows, d // block_d)
    beta2 = beta.reshape(n, 1).astype(fresh.dtype)
    mask2 = mask.reshape(n, 1).astype(fresh.dtype)
    if stream:
        kernel = functools.partial(_comp_stream_kernel, block_rows=block_rows,
                                   block_d=block_d, grid_j=grid[1])
        store_spec = pl.BlockSpec(memory_space=pltpu.ANY)  # stays in HBM
        # DMA is byte-exact: the double buffer must carry the store dtype
        scratch = [pltpu.VMEM((2, block_rows, block_d), store.dtype),
                   pltpu.SemaphoreType.DMA((2,))]
    else:
        kernel = functools.partial(_comp_resident_kernel,
                                   block_rows=block_rows)
        # lint: ok(R003) legacy resident path: stream=True is the default and
        # Mosaic rejects >12 MiB blocks at compile time; kept for small
        # stores + streamed-vs-resident benchmarking (module docstring)
        store_spec = pl.BlockSpec((m, block_d), lambda i, j, gid: (0, j))
        scratch = [pltpu.VMEM((block_rows, block_d), fresh.dtype)]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,  # gids -> SMEM
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, 1), lambda i, j, gid: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i, j, gid: (i, 0)),
            pl.BlockSpec((block_rows, block_d), lambda i, j, gid: (i, j)),
            store_spec,
        ],
        out_specs=pl.BlockSpec((block_rows, block_d), lambda i, j, gid: (i, j)),
        scratch_shapes=scratch,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, d), fresh.dtype),
        interpret=interpret,
    )(gids, beta2, mask2, fresh, store)
