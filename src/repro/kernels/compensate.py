"""Fused LMC compensation kernel — Eq. (9)/(12)'s gather + convex-combine.

The per-halo-node update  ĥ_i = (1-β_i)·H̄[gid_i] + β_i·h̃_i  is a gather from
the (node-sharded) historical store fused with the lerp and validity mask, so
the historical row never round-trips through HBM twice. Tiles follow the same
(rows × feature-block) layout as the SpMM kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _comp_kernel(gid_ref, beta_ref, mask_ref, fresh_ref, store_ref, o_ref):
    bn, bd = o_ref.shape

    def row_body(i, _):
        g = gid_ref[i]
        hist = pl.load(store_ref, (pl.dslice(g, 1), slice(None)))[0]
        b = beta_ref[i]
        out = mask_ref[i] * ((1.0 - b) * hist + b * fresh_ref[i, :])
        pl.store(o_ref, (pl.dslice(i, 1), slice(None)), out[None])
        return 0

    jax.lax.fori_loop(0, bn, row_body, 0)


@functools.partial(jax.jit, static_argnames=("block_rows", "block_d",
                                             "interpret"))
def lmc_compensate(store: jax.Array, gids: jax.Array, beta: jax.Array,
                   fresh: jax.Array, mask: jax.Array, *,
                   block_rows: int = 256, block_d: int = 128,
                   interpret: bool = True) -> jax.Array:
    """store (M, D); gids/beta/mask (N,); fresh (N, D) -> (N, D)."""
    n, d = fresh.shape
    m = store.shape[0]
    assert n % block_rows == 0 and d % block_d == 0, (n, d)
    grid = (n // block_rows, d // block_d)
    return pl.pallas_call(
        _comp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows,), lambda i, j: (i,)),
            pl.BlockSpec((block_rows,), lambda i, j: (i,)),
            pl.BlockSpec((block_rows,), lambda i, j: (i,)),
            pl.BlockSpec((block_rows, block_d), lambda i, j: (i, j)),
            pl.BlockSpec((m, block_d), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_rows, block_d), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, d), fresh.dtype),
        interpret=interpret,
    )(gids, beta, mask, fresh, store)
