"""jit'd, differentiable production wrappers around the Pallas kernels.

`bucketed_spmm` is the deployable aggregation: rows are degree-bucketed host
side (powers of two) so ELL padding waste stays < 2x, each bucket runs one
`ell_spmm` pallas_call, and the results concatenate back in row order. It is a
`jax.custom_vjp`: the transpose of an ELL SpMM is an SpMM over the transposed
adjacency, so `build_ell` also buckets Aᵀ and the backward pass runs through
the same kernel (this is what lets `core/lmc.py`'s per-layer ``jax.vjp`` calls
stay on the kernel path — DESIGN.md §3).

`lmc_compensate` is the differentiable, shape-padding entry point for the
fused gather+lerp compensation kernel (Eq. 9/12); its VJP scatters the store
cotangent and keeps β/mask/fresh gradients exact against the jnp oracle.

`build_ell` / `ell_from_coo` are bulk-numpy preprocessors (degree bucketing
via repeat/searchsorted, heavy-row splitting via chunk index arithmetic — no
per-node Python loop); `ell_from_coo` additionally fixes per-bucket row
capacities from the padded batch sizes so every mini-batch of a sampler
traces to the same jit shapes.

`ell_aggregate_fn` adapts the SpMM to the GNN `AggregateFn` interface so the
paper's models can swap the jnp segment-sum oracle for the kernel with one
argument; the train step selects it with ``make_train_step(...,
backend="ell")``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.compensate import lmc_compensate_kernel
from repro.kernels.ell_spmm import default_interpret, default_stream, ell_spmm
from repro.kernels import ref


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


class ELLCapacityError(ValueError):
    """A bucket's real row count exceeds its fixed padded capacity.

    Raised by the host-side builders (``build_ell``/``ell_from_coo``) when
    ``row_capacity`` is given and a degree bucket would need more rows than
    the fixed shape allows — the alternative, silent truncation, would drop
    edges and corrupt aggregations. Catch it to rebuild with larger
    capacities (or let ``fixed_capacity=True`` derive worst-case ones).
    """


def _pick_block_rows(rows: int) -> int:
    """Largest power-of-two tile height ≤ 256 dividing the padded row count."""
    for b in (256, 128, 64, 32, 16, 8):
        if rows % b == 0:
            return b
    raise ValueError(f"ELL bucket rows {rows} not a multiple of 8")


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class ELLGraph:
    """Degree-bucketed padded-ELL adjacency (host-built, device arrays).

    Registered as a pytree so it can ride through ``jit`` (as part of a Batch)
    and through ``jax.custom_vjp``: the index/weight/row arrays are children,
    the row/col counts are static aux data, and ``transpose`` (the bucketed
    Aᵀ, used by the SpMM VJP) is a nested child.
    """
    bucket_idx: tuple      # per bucket: (rows_b, K_b) int32 neighbor ids
    bucket_w: tuple        # per bucket: (rows_b, K_b) f32 weights
    bucket_rows: tuple     # per bucket: (rows_b,) int32 destination rows
    num_rows: int          # output rows (static)
    num_cols: int          # gather-source rows, == h.shape[0] (static)
    transpose: Optional["ELLGraph"] = None

    def tree_flatten(self):
        return ((self.bucket_idx, self.bucket_w, self.bucket_rows,
                 self.transpose), (self.num_rows, self.num_cols))

    @classmethod
    def tree_unflatten(cls, aux, children):
        idx, w, rows, t = children
        return cls(bucket_idx=idx, bucket_w=w, bucket_rows=rows,
                   num_rows=aux[0], num_cols=aux[1], transpose=t)


# --------------------------------------------------------------- host builders
def _ell_buckets(indptr: np.ndarray, indices: np.ndarray, weights: np.ndarray,
                 buckets: Sequence[int], block_rows: int,
                 row_capacity: Optional[Sequence[int]], as_jax: bool = True):
    """CSR -> per-bucket (idx, w, rows) arrays, fully vectorized.

    Reproduces the row order of the original per-node loop exactly: rows are
    emitted in (node, chunk) order; each chunk of ≤ kmax neighbors lands in
    the smallest bucket that fits it; deg-0 nodes emit one empty bucket-0 row.
    ``as_jax=False`` keeps the bucket arrays as host numpy (the prefetch
    pipeline builds batches off-thread and lets the consumer ``device_put``).
    """
    n = indptr.shape[0] - 1
    deg = np.diff(indptr).astype(np.int64)
    kmax = int(buckets[-1])

    # one row per kmax-chunk of each neighbor list (deg-0 nodes get one chunk)
    nchunks = np.maximum((deg + kmax - 1) // kmax, 1)
    row_node = np.repeat(np.arange(n, dtype=np.int64), nchunks)
    first = np.zeros(n, np.int64)
    first[1:] = np.cumsum(nchunks)[:-1]
    chunk_start = (np.arange(row_node.shape[0], dtype=np.int64)
                   - np.repeat(first, nchunks)) * kmax
    chunk_len = np.clip(deg[row_node] - chunk_start, 0, kmax)
    bucket_of = np.searchsorted(np.asarray(buckets, np.int64), chunk_len)

    b_idx, b_w, b_rows = [], [], []
    for b, k in enumerate(buckets):
        sel = np.flatnonzero(bucket_of == b)   # preserves (node, chunk) order
        rows = sel.shape[0]
        if row_capacity is not None:
            rows_pad = int(row_capacity[b])
            if rows > rows_pad:
                raise ELLCapacityError(
                    f"bucket {b} (K={k}): {rows} rows exceed capacity {rows_pad}")
        else:
            rows_pad = max(_round_up(rows, block_rows), block_rows)
        idx = np.zeros((rows_pad, k), np.int32)
        w = np.zeros((rows_pad, k), np.float32)
        rid = np.full((rows_pad,), n, np.int32)  # pad rows -> dropped
        if rows:
            if indices.shape[0]:
                base = indptr[row_node[sel]] + chunk_start[sel]
                offs = np.arange(k, dtype=np.int64)
                valid = offs[None, :] < chunk_len[sel][:, None]
                pos = np.where(valid, base[:, None] + offs[None, :], 0)
                idx[:rows] = np.where(valid, indices[pos], 0).astype(np.int32)
                w[:rows] = np.where(valid, weights[pos], 0.0).astype(np.float32)
            # else: edgeless graph — every row is an all-padding deg-0 row
            rid[:rows] = row_node[sel].astype(np.int32)
        conv = jnp.asarray if as_jax else (lambda a: a)
        b_idx.append(conv(idx))
        b_w.append(conv(w))
        b_rows.append(conv(rid))
    return tuple(b_idx), tuple(b_w), tuple(b_rows)


def _build_ell_loop(indptr, indices, weights, buckets=(8, 32, 128),
                    block_rows: int = 256):
    """Original per-node Python-loop builder.

    Kept only as the correctness reference for the vectorized `build_ell`
    (property-tested against it) and as the baseline of the preprocessing
    benchmark; O(n) interpreted Python — do not use on large graphs.
    """
    n = indptr.shape[0] - 1
    kmax = buckets[-1]
    b_idx, b_w, b_rows = [], [], []
    row_ids = [[] for _ in buckets]
    row_idx = [[] for _ in buckets]
    row_ws = [[] for _ in buckets]

    for v in range(n):
        lo, hi = indptr[v], indptr[v + 1]
        nbrs, ws = indices[lo:hi], weights[lo:hi]
        for s in range(0, max(len(nbrs), 1), kmax):
            part_n = nbrs[s:s + kmax]
            part_w = ws[s:s + kmax]
            b = next(i for i, k in enumerate(buckets) if len(part_n) <= k)
            k = buckets[b]
            pad = k - len(part_n)
            row_ids[b].append(v)
            row_idx[b].append(np.pad(part_n.astype(np.int32), (0, pad)))
            row_ws[b].append(np.pad(part_w.astype(np.float32), (0, pad)))

    for b, k in enumerate(buckets):
        rows = len(row_ids[b])
        rows_pad = max(_round_up(rows, block_rows), block_rows)
        idx = np.zeros((rows_pad, k), np.int32)
        w = np.zeros((rows_pad, k), np.float32)
        rid = np.full((rows_pad,), n, np.int32)
        if rows:
            idx[:rows] = np.stack(row_idx[b])
            w[:rows] = np.stack(row_ws[b])
            rid[:rows] = np.asarray(row_ids[b], np.int32)
        b_idx.append(jnp.asarray(idx))
        b_w.append(jnp.asarray(w))
        b_rows.append(jnp.asarray(rid))
    return ELLGraph(tuple(b_idx), tuple(b_w), tuple(b_rows),
                    num_rows=n, num_cols=n)


def _transpose_csr(indptr, indices, weights, num_cols):
    """CSR of A -> CSR of Aᵀ (bulk numpy: one argsort over the edge list)."""
    n = indptr.shape[0] - 1
    rows = np.repeat(np.arange(n, dtype=np.int64), np.diff(indptr))
    order = np.argsort(indices, kind="stable")
    counts = np.bincount(indices, minlength=num_cols)
    t_indptr = np.zeros(num_cols + 1, np.int64)
    t_indptr[1:] = np.cumsum(counts)
    return t_indptr, rows[order].astype(np.int32), \
        np.asarray(weights)[order].astype(np.float32)


def build_ell(indptr: np.ndarray, indices: np.ndarray, weights: np.ndarray,
              buckets=(8, 32, 128), block_rows: int = 256, *,
              num_cols: Optional[int] = None,
              row_capacity: Optional[Sequence[int]] = None,
              with_transpose: bool = True, as_jax: bool = True) -> ELLGraph:
    """CSR -> degree-bucketed ELL (bulk numpy, no per-node Python loop).

    Rows with deg > max(buckets) are split into multiple partial rows (their
    partial sums add via the final scatter-add, keeping K bounded). When
    ``with_transpose`` the transposed adjacency is bucketed too, giving the
    SpMM its custom-VJP backward graph. ``row_capacity`` (per-bucket padded
    row counts, applied to both directions) fixes the array shapes so every
    batch of a sampler hits one jit trace.
    """
    indptr = np.asarray(indptr, np.int64)
    indices = np.asarray(indices)
    weights = np.asarray(weights)
    n = indptr.shape[0] - 1
    num_cols = n if num_cols is None else int(num_cols)

    idx, w, rows = _ell_buckets(indptr, indices, weights, buckets, block_rows,
                                row_capacity, as_jax)
    t = None
    if with_transpose:
        t_ptr, t_ind, t_w = _transpose_csr(indptr, indices, weights, num_cols)
        ti, tw, tr = _ell_buckets(t_ptr, t_ind, t_w, buckets, block_rows,
                                  row_capacity, as_jax)
        t = ELLGraph(ti, tw, tr, num_rows=num_cols, num_cols=n)
    return ELLGraph(idx, w, rows, num_rows=n, num_cols=num_cols, transpose=t)


def fixed_row_capacity(num_rows: int, num_edges: int, buckets=(8, 32, 128),
                       block_rows: int = 256) -> tuple:
    """Worst-case per-bucket row counts for any graph with ≤ num_edges edges
    over num_rows rows: each row emits ≤ 1 remainder chunk (any bucket) plus
    full-kmax chunks (last bucket only, ≤ E/kmax in total)."""
    caps = [max(_round_up(max(num_rows, 1), block_rows), block_rows)
            for _ in buckets]
    caps[-1] = max(_round_up(max(num_rows, 1) + num_edges // int(buckets[-1]),
                             block_rows), block_rows)
    return tuple(caps)


def ell_from_coo(src: np.ndarray, dst: np.ndarray, w: np.ndarray,
                 num_rows: int, *, buckets=(8, 32, 128),
                 block_rows: int = 256, fixed_capacity: bool = True,
                 as_jax: bool = True) -> ELLGraph:
    """Padded local COO (a PaddedSubgraph's edge list) -> square ELLGraph.

    Aggregation semantics match ``models.gnn.segment_spmm``: out[dst] +=
    w·h[src]; padded edges (w == 0) contribute nothing. With
    ``fixed_capacity`` the bucket shapes depend only on (num_rows, E), so all
    batches of a sampler share one jit trace. ``as_jax=False`` leaves the
    bucket arrays on the host (numpy) for deferred ``jax.device_put``.
    """
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    w = np.asarray(w, np.float32)
    order = np.argsort(dst, kind="stable")
    counts = np.bincount(dst, minlength=num_rows)
    indptr = np.zeros(num_rows + 1, np.int64)
    indptr[1:] = np.cumsum(counts)
    caps = (fixed_row_capacity(num_rows, src.shape[0], buckets, block_rows)
            if fixed_capacity else None)
    return build_ell(indptr, src[order], w[order], buckets, block_rows,
                     num_cols=num_rows, row_capacity=caps, as_jax=as_jax)


# ------------------------------------------------------------ kernel wrappers
def _bucketed_spmm_impl(g: ELLGraph, h: jax.Array, interpret: bool,
                        stream: bool) -> jax.Array:
    """out[i] = Σ_{j in N(i)} w_ij h[j] over all degree buckets."""
    n = g.num_rows
    d = h.shape[1]
    d_pad = _round_up(d, 128)
    hp = jnp.pad(h, ((0, 0), (0, d_pad - d))) if d_pad != d else h
    out = jnp.zeros((n + 1, d_pad), h.dtype)   # row n catches padding rows
    for idx, w, rows in zip(g.bucket_idx, g.bucket_w, g.bucket_rows):
        part = ell_spmm(idx, w, hp, block_rows=_pick_block_rows(idx.shape[0]),
                        interpret=interpret, stream=stream)
        out = out.at[rows].add(part.astype(h.dtype), mode="drop")
    return out[:n, :d]


def _zeros_cotangent(tree):
    """Zero cotangents for a pytree with integer leaves (float0 for ints)."""
    def z(x):
        x = jnp.asarray(x)
        if jnp.issubdtype(x.dtype, jnp.integer) or x.dtype == jnp.bool_:
            return np.zeros(x.shape, jax.dtypes.float0)
        return jnp.zeros_like(x)
    return jax.tree.map(z, tree)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _bucketed_spmm_vjp(interpret: bool, stream: bool, g: ELLGraph,
                       h: jax.Array):
    return _bucketed_spmm_impl(g, h, interpret, stream)


def _bucketed_spmm_fwd(interpret, stream, g, h):
    return _bucketed_spmm_impl(g, h, interpret, stream), (g, h)


def _bucketed_spmm_bwd(interpret, stream, res, ct):
    g, h = res
    if g.transpose is None:
        raise ValueError(
            "bucketed_spmm: gradient requested but the ELLGraph was built "
            "with with_transpose=False; the SpMM VJP needs the bucketed Aᵀ")
    # the backward SpMM streams (or not) exactly like the forward: the Aᵀ
    # kernel's gather source is the cotangent, which is full-graph-sized
    # whenever the forward output was — the cap must not move to the bwd pass
    dh = _bucketed_spmm_impl(g.transpose, ct, interpret, stream)
    # weight cotangent dw[i,k] = ⟨ct[rows[i]], h[idx[i,k]]⟩ (jnp gather; XLA
    # DCEs it under jit when the caller only differentiates w.r.t. h, the
    # LMC train-step case). Row `num_rows` of the padded ct zeroes the
    # all-padding rows (rid == num_rows).
    ctp = jnp.pad(ct, ((0, 1), (0, 0)))
    dws = tuple(
        jnp.einsum("rd,rkd->rk", jnp.take(ctp, rows, axis=0, mode="clip"),
                   jnp.take(h, idx, axis=0, mode="clip")).astype(w.dtype)
        for idx, w, rows in zip(g.bucket_idx, g.bucket_w, g.bucket_rows))
    dg = dataclasses.replace(_zeros_cotangent(g), bucket_w=dws)
    return dg, dh


_bucketed_spmm_vjp.defvjp(_bucketed_spmm_fwd, _bucketed_spmm_bwd)


def bucketed_spmm(g: ELLGraph, h: jax.Array, *,
                  interpret: bool | None = None,
                  stream: bool | None = None) -> jax.Array:
    """Differentiable bucketed ELL SpMM: out = A h.

    VJP: dh = Aᵀ(dout) through the transposed-bucket kernel (streamed like
    the forward, so a full-graph-sized cotangent never needs a resident VMEM
    block); d(bucket_w) via jnp gathers (padding slots get the would-be-edge
    gradient ct·h[0], which is meaningless but never read back — ELL weights
    map to CSR entries only where the builder placed real edges).

    ``stream=None`` autodetects to the HBM→VMEM DMA gather (no VMEM bound on
    h's row count); ``stream=False`` forces the legacy resident feature block
    (small sources / benchmarking).
    """
    if interpret is None:
        interpret = default_interpret()
    if stream is None:
        stream = default_stream()
    return _bucketed_spmm_vjp(bool(interpret), bool(stream), g, h)


def _compensate_impl(store, gids, beta, fresh, mask, interpret, stream):
    n, d = fresh.shape
    d_pad = _round_up(d, 128)
    block = 256 if n >= 256 else _round_up(max(n, 8), 8)
    n_pad = _round_up(n, block)
    sp = jnp.pad(store, ((0, 0), (0, d_pad - d))) if d_pad != d else store
    fp = fresh
    if d_pad != d or n_pad != n:
        fp = jnp.pad(fresh, ((0, n_pad - n), (0, d_pad - d)))
    pad1 = ((0, n_pad - n),)
    gp = jnp.pad(gids, pad1) if n_pad != n else gids
    bp = jnp.pad(beta, pad1) if n_pad != n else beta
    mp = jnp.pad(mask, pad1) if n_pad != n else mask
    out = lmc_compensate_kernel(sp, gp, bp, fp, mp, block_rows=block,
                                interpret=interpret, stream=stream)
    return out[:n, :d]


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _lmc_compensate_vjp(interpret, stream, store, gids, beta, fresh, mask):
    return _compensate_impl(store, gids, beta, fresh, mask, interpret, stream)


def _compensate_fwd(interpret, stream, store, gids, beta, fresh, mask):
    out = _compensate_impl(store, gids, beta, fresh, mask, interpret, stream)
    return out, (store, gids, beta, fresh, mask)


def _compensate_bwd(interpret, stream, res, ct):
    # The adjoint is VMEM-cap-free by construction at any store size: the
    # hist gather and the d_store scatter-add lower to XLA gather/scatter
    # over HBM-resident operands (no (M, block_d) VMEM residency anywhere),
    # so streaming the forward never shifts a resident-block cap here.
    del stream
    store, gids, beta, fresh, mask = res
    hist = jnp.take(store, gids, axis=0, mode="clip")
    d_store = jnp.zeros_like(store).at[gids].add(
        ((mask * (1.0 - beta))[:, None] * ct).astype(store.dtype))
    d_beta = jnp.sum(ct * mask[:, None] * (fresh - hist),
                     axis=-1).astype(beta.dtype)
    d_fresh = (ct * (mask * beta)[:, None]).astype(fresh.dtype)
    d_mask = jnp.sum(ct * ((1.0 - beta)[:, None] * hist
                           + beta[:, None] * fresh), axis=-1).astype(mask.dtype)
    d_gids = np.zeros(gids.shape, jax.dtypes.float0)
    return d_store, d_gids, d_beta, d_fresh, d_mask


_lmc_compensate_vjp.defvjp(_compensate_fwd, _compensate_bwd)


def lmc_compensate(store: jax.Array, gids: jax.Array, beta: jax.Array,
                   fresh: jax.Array, mask: jax.Array, *,
                   interpret: bool | None = None,
                   stream: bool | None = None) -> jax.Array:
    """ĥ = mask · [(1-β)·store[gid] + β·fresh]  (Eq. 9/12), differentiable.

    store (M, D); gids/beta/mask (N,); fresh (N, D) -> (N, D). Arbitrary N/D
    (padded internally to kernel tiles); VJP is exact against the jnp oracle,
    including the scatter-add store cotangent (an XLA HBM scatter — no
    resident VMEM block, so the backward pass is cap-free at any M).

    ``stream=None`` autodetects to the HBM→VMEM DMA store gather: the
    *full-graph* historical store stays in HBM and only the gathered rows
    cross into VMEM, so the compiled path has no bound on the store row
    count. ``stream=False`` forces the legacy resident store block (small
    stores / benchmarking only).

    Perf note: when D is not a multiple of 128 the *whole store* is padded to
    the tile width on every call — keep hidden dims 128-aligned in production
    (the pad is then a no-op).
    """
    if interpret is None:
        interpret = default_interpret()
    if stream is None:
        stream = default_stream()
    return _lmc_compensate_vjp(bool(interpret), bool(stream), store, gids,
                               beta, fresh, mask)


def ell_aggregate_fn(g: ELLGraph, *, interpret: bool | None = None,
                     stream: bool | None = None):
    """AggregateFn adapter for repro.models.gnn (ignores the COO edge list —
    the ELL graph already encodes the same adjacency)."""
    def aggregate(edges, h, num_rows):
        del edges
        out = bucketed_spmm(g, h, interpret=interpret, stream=stream)
        assert out.shape[0] == num_rows
        return out
    return aggregate


__all__ = ["ELLGraph", "build_ell", "ell_from_coo", "fixed_row_capacity",
           "bucketed_spmm", "ell_spmm", "lmc_compensate", "ell_aggregate_fn",
           "default_interpret", "default_stream", "ref"]
