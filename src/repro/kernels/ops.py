"""jit'd production wrappers around the Pallas kernels.

`bucketed_spmm` is the deployable aggregation: rows are degree-bucketed host
side (powers of two) so ELL padding waste stays < 2x, each bucket runs one
`ell_spmm` pallas_call, and the results concatenate back in row order.
`ell_aggregate_fn` adapts it to the GNN `AggregateFn` interface so the paper's
models can swap the jnp segment-sum oracle for the kernel with one argument.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.compensate import lmc_compensate
from repro.kernels.ell_spmm import ell_spmm
from repro.kernels import ref


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


class ELLGraph(NamedTuple):
    """Degree-bucketed padded-ELL adjacency (host-built, device arrays)."""
    bucket_idx: tuple      # per bucket: (rows_b, K_b) int32 neighbor ids
    bucket_w: tuple        # per bucket: (rows_b, K_b) f32 weights
    bucket_rows: tuple     # per bucket: (rows_b,) int32 destination rows
    num_rows: int


def build_ell(indptr: np.ndarray, indices: np.ndarray, weights: np.ndarray,
              buckets=(8, 32, 128), block_rows: int = 256) -> ELLGraph:
    """CSR -> degree-bucketed ELL. Rows with deg > max(buckets) are split
    into multiple partial rows (their partial sums add via the final
    scatter-add, keeping K bounded)."""
    n = indptr.shape[0] - 1
    deg = np.diff(indptr)
    kmax = buckets[-1]
    b_idx, b_w, b_rows = [], [], []
    row_ids = [[] for _ in buckets]
    row_idx = [[] for _ in buckets]
    row_ws = [[] for _ in buckets]

    for v in range(n):
        lo, hi = indptr[v], indptr[v + 1]
        nbrs, ws = indices[lo:hi], weights[lo:hi]
        # split heavy rows into K-sized partial rows
        for s in range(0, max(len(nbrs), 1), kmax):
            part_n = nbrs[s:s + kmax]
            part_w = ws[s:s + kmax]
            b = next(i for i, k in enumerate(buckets) if len(part_n) <= k)
            k = buckets[b]
            pad = k - len(part_n)
            row_ids[b].append(v)
            row_idx[b].append(np.pad(part_n.astype(np.int32), (0, pad)))
            row_ws[b].append(np.pad(part_w.astype(np.float32), (0, pad)))

    for b, k in enumerate(buckets):
        rows = len(row_ids[b])
        rows_pad = max(_round_up(rows, block_rows), block_rows)
        idx = np.zeros((rows_pad, k), np.int32)
        w = np.zeros((rows_pad, k), np.float32)
        rid = np.full((rows_pad,), n, np.int32)  # pad rows -> dropped
        if rows:
            idx[:rows] = np.stack(row_idx[b])
            w[:rows] = np.stack(row_ws[b])
            rid[:rows] = np.asarray(row_ids[b], np.int32)
        b_idx.append(jnp.asarray(idx))
        b_w.append(jnp.asarray(w))
        b_rows.append(jnp.asarray(rid))
    return ELLGraph(tuple(b_idx), tuple(b_w), tuple(b_rows), n)


def bucketed_spmm(g: ELLGraph, h: jax.Array, *, interpret: bool = True
                  ) -> jax.Array:
    """out[i] = Σ_{j in N(i)} w_ij h[j] over all degree buckets."""
    n = g.num_rows
    d = h.shape[1]
    d_pad = _round_up(d, 128)
    hp = jnp.pad(h, ((0, 0), (0, d_pad - d))) if d_pad != d else h
    out = jnp.zeros((n + 1, d_pad), h.dtype)
    for idx, w, rows in zip(g.bucket_idx, g.bucket_w, g.bucket_rows):
        part = ell_spmm(idx, w, hp, interpret=interpret)
        out = out.at[rows].add(part, mode="drop")
    return out[:n, :d]


def ell_aggregate_fn(g: ELLGraph, *, interpret: bool = True):
    """AggregateFn adapter for repro.models.gnn (ignores the COO edge list —
    the ELL graph already encodes the same adjacency)."""
    def aggregate(edges, h, num_rows):
        del edges
        out = bucketed_spmm(g, h, interpret=interpret)
        assert out.shape[0] == num_rows
        return out
    return aggregate


__all__ = ["ELLGraph", "build_ell", "bucketed_spmm", "ell_spmm",
           "lmc_compensate", "ell_aggregate_fn", "ref"]
