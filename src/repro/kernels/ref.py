"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ell_spmm_ref(nbr_idx: jax.Array, nbr_w: jax.Array, h: jax.Array
                 ) -> jax.Array:
    """out[i] = Σ_k w[i,k] · h[idx[i,k]].   idx/w: (N, K); h: (M, D).

    Padding entries carry w == 0 (idx may point anywhere valid).
    """
    gathered = h[nbr_idx]                      # (N, K, D)
    return jnp.einsum("nk,nkd->nd", nbr_w, gathered)


def lmc_compensate_ref(store: jax.Array, gids: jax.Array, beta: jax.Array,
                       fresh: jax.Array, mask: jax.Array) -> jax.Array:
    """ĥ = mask · [(1-β)·store[gid] + β·fresh]   (paper Eq. 9 / Eq. 12)."""
    hist = store[gids]                         # (N, D)
    return (mask[:, None] * ((1.0 - beta[:, None]) * hist
                             + beta[:, None] * fresh))


def degree_bucket_spmm_ref(indptr, indices, weights, h):
    """CSR segment-sum oracle used by the bucketed production wrapper."""
    n = indptr.shape[0] - 1
    src = jnp.repeat(jnp.arange(n), jnp.diff(indptr),
                     total_repeat_length=indices.shape[0])
    msgs = h[indices] * weights[:, None]
    return jax.ops.segment_sum(msgs, src, num_segments=n)
