"""GNNServer: batched, fault-tolerant inference over the historical store.

The serving insight (DESIGN.md §12): LMC's historical store is a full-graph
embedding cache, so answering "classify nodes T" does not need T's exponential
receptive field — gather the cached layer values for T's 1-hop halo, run only
the mini-batch forward (``core.lmc.make_infer_step``), and refresh the touched
rows. With an exact store the answer *equals* the full-graph forward.

One worker thread owns the store and drains a bounded admission queue;
requests are coalesced for ``batch_window_s`` and padded into one of a few
fixed-shape buckets (gateway.py) so every batch hits a compiled trace. The
robustness ladder around that hot path:

  admission   — ``queue.Queue(maxsize=queue_depth)`` + ``put_nowait``: a full
                queue sheds with a typed Overloaded response, never blocks;
  deadlines   — per-request budgets checked before, during (injected stalls)
                and after execution → typed timeout responses;
  degradation — policy.py decides exact vs store-free ti per batch (breaker,
                ρ-staleness vs the shared Thm-2 budget, per-row crc32);
  breaker     — non-finite exact output trips to ti-only, heals after N clean
                probes (policy.CircuitBreaker);
  repair      — offending rows are recomputed store-free and written back,
                so degradation is transient, not sticky;
  retry       — transient execution failures (injected worker crashes) get
                ``max_attempts`` in-place retries with backoff;
  drain       — close() stops admission, completes everything in flight, and
                resolves any racing submissions with a typed closed response:
                every accepted future is always resolved.

Everything here is host-side threading; the device work is the jitted infer
steps. FaultPlan (train/health.py) injects the serving fault classes.
"""
from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import Future
from concurrent.futures import TimeoutError as FutureTimeout
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.exact import FullGraphData, exact_layer_values, from_graph
from repro.core.history import HistoricalState
from repro.core.lmc import make_infer_step
from repro.graph.structure import Graph
from repro.models.gnn import GNN
from repro.serve.gateway import StoreGateway
from repro.serve.policy import (MODE_EXACT, MODE_TI, CircuitBreaker,
                                DegradationPolicy, ServeConfig, StoreIntegrity)
from repro.serve.types import (STATUS_CLOSED, STATUS_DEGRADED, STATUS_ERROR,
                               STATUS_OK, STATUS_OVERLOADED, STATUS_TIMEOUT,
                               STATUS_TOO_LARGE, ServeResponse)
from repro.train.health import (FaultPlan, HealthConfig, HealthGuard,
                                ServeWorkerFault)

_POLL_S = 0.02   # worker idle poll; get() returns immediately on arrival


class _NonFinite(Exception):
    """Internal: batch output contained NaN/Inf (circuit-breaker trigger)."""


@dataclass
class _Pending:
    """An admitted request riding through the worker."""

    nodes: np.ndarray
    request_id: str
    deadline: Optional[float]      # absolute time.time() bound, or None
    t_submit: float
    future: Future = field(default_factory=Future)


def warm_store(gnn: GNN, params: dict, data: FullGraphData) -> HistoricalState:
    """Exact-layer-value store (core/exact.py): the healthy serving state.

    ``store.h[l]`` holds the exact output of layer ``l`` for every node, so
    the exact serving path reproduces the full-graph forward. ``v`` (backward
    aux) is unused by inference and left zero.
    """
    hs, _ = exact_layer_values(gnn, params, data)
    # lint: ok(R001) one-time store warmup on unsharded single-device arrays
    h = jnp.stack(hs)
    return HistoricalState(h=h, v=jnp.zeros(
        (max(gnn.num_layers - 1, 1),) + h.shape[1:], h.dtype))


class GNNServer:
    """Batched GNN inference server over the LMC historical store."""

    def __init__(self, gnn: GNN, graph: Graph, params: dict, *,
                 store: Optional[HistoricalState] = None,
                 config: Optional[ServeConfig] = None,
                 fault_plan: Optional[FaultPlan] = None,
                 data: Optional[FullGraphData] = None):
        """Start the server (one worker thread; returns ready to accept).

        ``store=None`` warms an exact store from ``params`` (one full-graph
        forward). ``data`` may share a prebuilt FullGraphData.
        """
        self.config = config or ServeConfig()
        self.config.validate()
        self.gnn, self.graph, self.params = gnn, graph, params
        self.fault_plan = fault_plan
        self.data = data if data is not None else from_graph(graph)
        self._x, self._self_w = self.data.x, self.data.self_w
        n, L = graph.num_nodes, gnn.num_layers
        self._store = store if store is not None \
            else warm_store(gnn, params, self.data)

        cfg = self.config
        self.gateway = StoreGateway(graph, buckets=cfg.buckets,
                                    agg_backend=cfg.backend,
                                    ell_buckets=cfg.ell_buckets)
        self._guard = HealthGuard(HealthConfig(rho_budget=cfg.rho_budget),
                                  L, n)
        self._integrity = StoreIntegrity(L, n)
        self._integrity.record(
            np.arange(n), np.asarray(jax.device_get(self._store.h)))
        self._breaker = CircuitBreaker(heal_after=cfg.breaker_heal_after,
                                       cooldown=cfg.breaker_cooldown)
        self._policy = DegradationPolicy(cfg, self._guard, self._integrity,
                                         self._breaker)
        self._steps = {
            MODE_EXACT: jax.jit(make_infer_step(
                gnn, n, backend=cfg.backend, fwd_mode="historical",
                compensation="store", refresh=True, stream=cfg.stream)),
            MODE_TI: jax.jit(make_infer_step(
                gnn, n, backend=cfg.backend, fwd_mode=cfg.ti_fwd_mode,
                compensation="ti", refresh=False, stream=cfg.stream)),
            "repair": jax.jit(make_infer_step(
                gnn, n, backend=cfg.backend, fwd_mode=cfg.ti_fwd_mode,
                compensation="ti", refresh=True, stream=cfg.stream)),
        }

        if cfg.warmup:
            self.warm_traces()

        self._q: queue.Queue = queue.Queue(maxsize=cfg.queue_depth)
        self._carry: Optional[_Pending] = None
        self._closing = threading.Event()
        self._abort = threading.Event()
        self._mu = threading.Lock()        # store/staleness/integrity commits
        self._stat_mu = threading.Lock()   # counters (worker + submitters)
        self._counts: dict = {}
        self._seq = 0
        self.events: list = []
        self._worker = threading.Thread(target=self._worker_main,
                                        name="gnn-serve-worker", daemon=True)
        self._worker.start()

    # ------------------------------------------------------------- client API
    def submit(self, nodes, *, deadline_s: Optional[float] = None,
               request_id: str = "") -> Future:
        """Enqueue a request; returns a Future[ServeResponse].

        Never blocks and never raises: admission failures (queue full,
        oversized or malformed request, closing server) resolve the future
        immediately with the matching typed status.
        """
        now = time.time()
        budget = self.config.default_deadline_s if deadline_s is None \
            else deadline_s
        nodes = np.atleast_1d(np.asarray(nodes, dtype=np.int64))
        p = _Pending(nodes=nodes, request_id=request_id,
                     deadline=now + budget, t_submit=now)
        self._count("submitted")
        if self._closing.is_set():
            self._finish(p, STATUS_CLOSED, detail="server is shutting down")
        elif nodes.ndim != 1 or nodes.size == 0 \
                or nodes.min() < 0 or nodes.max() >= self.graph.num_nodes:
            self._finish(p, STATUS_ERROR,
                         detail="nodes must be a non-empty 1-d array of "
                                "in-range node ids")
        elif np.unique(nodes).size > self.gateway.max_targets:
            self._finish(p, STATUS_TOO_LARGE,
                         detail=f"{np.unique(nodes).size} targets > largest "
                                f"bucket {self.gateway.max_targets}")
        else:
            try:
                self._q.put_nowait(p)
            except queue.Full:
                self._count("shed")
                self._finish(p, STATUS_OVERLOADED,
                             detail=f"admission queue full "
                                    f"(depth {self.config.queue_depth})")
        return p.future

    def infer(self, nodes, *, deadline_s: Optional[float] = None,
              request_id: str = "") -> ServeResponse:
        """Synchronous submit+wait. Bounded: even a wedged worker yields a
        typed timeout response rather than a hang."""
        fut = self.submit(nodes, deadline_s=deadline_s,
                          request_id=request_id)
        budget = self.config.default_deadline_s if deadline_s is None \
            else deadline_s
        try:
            return fut.result(timeout=budget + 30.0)
        except FutureTimeout:
            return ServeResponse(request_id=request_id, status=STATUS_TIMEOUT,
                                 detail="no response within the hard bound")

    def warm_traces(self) -> None:
        """Compile every (bucket, mode) trace so requests never pay jit.

        Runs one dummy batch per bucket through the exact/ti/repair steps
        and discards the outputs — the store, integrity ledger and counters
        are untouched; only the jit caches fill.
        """
        n = self.graph.num_nodes
        for b in self.gateway.buckets:
            targets = np.arange(min(b, n), dtype=np.int64)
            _, hb = self.gateway.build(targets)
            batch = jax.device_put(hb)
            for step in self._steps.values():
                out, _ = step(self.params, self._store, batch,
                              self._x, self._self_w)
                jax.block_until_ready(out)

    def notify_update(self, steps: int = 1) -> None:
        """Age the store's staleness counters by ``steps`` training steps.

        Serving itself never ages rows — with frozen params a cached row
        stays exact forever; staleness means "training moved the params
        under the cache". A co-located trainer calls this per step; rows
        past the shared ρ-budget then degrade to ti until re-served (and
        thereby refreshed) or repaired.
        """
        with self._mu:
            self._guard.staleness += int(steps)

    def drain(self, timeout: float = 60.0) -> bool:
        """Graceful shutdown: complete everything admitted, then stop."""
        return self.close(drain=True, timeout=timeout)

    def close(self, *, drain: bool = True, timeout: float = 60.0) -> bool:
        """Stop the server; True iff the worker exited within ``timeout``.

        ``drain=True`` completes all queued batches first; ``drain=False``
        resolves them with a typed closed response. Either way no admitted
        future is left unresolved.
        """
        self._closing.set()
        if not drain:
            self._abort.set()
        self._worker.join(timeout=timeout)
        # resolve submissions that raced past the closing check
        while True:
            try:
                p = self._q.get_nowait()
            except queue.Empty:
                break
            self._finish(p, STATUS_CLOSED, detail="server closed")
        return not self._worker.is_alive()

    def stats(self) -> dict:
        """Counters + breaker state (all host-side, cheap)."""
        with self._stat_mu:
            out = dict(self._counts)
        out["batches"] = self._seq
        out["breaker"] = self._breaker.state
        out["pending"] = out.get("submitted", 0) - sum(
            out.get(k, 0) for k in (STATUS_OK, STATUS_DEGRADED,
                                    STATUS_OVERLOADED, STATUS_TIMEOUT,
                                    STATUS_TOO_LARGE, STATUS_CLOSED,
                                    STATUS_ERROR))
        return out

    @property
    def store(self) -> HistoricalState:
        """Current store (read-mostly; the worker owns writes)."""
        return self._store

    # -------------------------------------------------------------- internals
    def _count(self, key: str, inc: int = 1) -> None:
        with self._stat_mu:
            self._counts[key] = self._counts.get(key, 0) + inc

    def _event(self, kind: str, seq: int, detail: str = "") -> None:
        self.events.append({"kind": kind, "seq": seq, "detail": detail})

    def _finish(self, p: _Pending, status: str, *, classes=None, logits=None,
                mode=None, reason=None, attempts: int = 0, seq: int = -1,
                detail: str = "") -> None:
        if p.future.done():
            return
        self._count(status)
        p.future.set_result(ServeResponse(
            request_id=p.request_id, status=status, classes=classes,
            logits=logits, mode=mode, degraded_reason=reason,
            latency_s=time.time() - p.t_submit, attempts=attempts,
            batch_seq=seq, detail=detail))

    def _worker_main(self) -> None:
        while True:
            p = self._carry
            self._carry = None
            if p is None:
                try:
                    p = self._q.get(timeout=_POLL_S)
                except queue.Empty:
                    if self._closing.is_set():
                        return
                    continue
            if self._abort.is_set():
                self._finish(p, STATUS_CLOSED, detail="server closed")
                continue
            pend = self._collect(p)
            self._seq += 1
            try:
                self._execute(pend, self._seq)
            except BaseException as e:  # worker must never die silently
                self._count("worker_restarts")
                self._event("worker-crash", self._seq, repr(e))
                for q_ in pend:
                    self._finish(q_, STATUS_ERROR, seq=self._seq,
                                 detail=f"unrecovered worker fault: {e!r}")

    def _collect(self, first: _Pending) -> list:
        """Coalesce queued requests behind ``first`` into one bucket batch."""
        pend = [first]
        total = first.nodes.shape[0]
        cap = self.gateway.max_targets
        t_end = time.time() + self.config.batch_window_s
        while total < cap:
            try:
                nxt = self._q.get(timeout=max(0.0, t_end - time.time()))
            except queue.Empty:
                break
            if total + nxt.nodes.shape[0] > cap:
                self._carry = nxt   # consumed first on the next iteration
                break
            pend.append(nxt)
            total += nxt.nodes.shape[0]
        return pend

    def _expire(self, live: list, seq: int, detail: str) -> list:
        now = time.time()
        kept = []
        for p in live:
            if p.deadline is not None and now > p.deadline:
                self._finish(p, STATUS_TIMEOUT, seq=seq, detail=detail)
            else:
                kept.append(p)
        return kept

    def _execute(self, pend: list, seq: int) -> None:
        cfg, plan = self.config, self.fault_plan
        live = self._expire(pend, seq, "deadline expired in queue")
        if not live:
            return
        # ---- injected slow/hung batch: deadlines turn the stall into
        # typed timeouts instead of a hang
        delay = plan.serve_delay(seq) if plan else 0.0
        if delay:
            self._event("slow-batch", seq, f"injected {delay:.3f}s stall")
            time.sleep(delay)
            live = self._expire(live, seq, "deadline expired during stall")
            if not live:
                return

        all_nodes = np.concatenate([p.nodes for p in live])
        uniq, inv = np.unique(all_nodes, return_inverse=True)
        try:
            sg, hb = self.gateway.build(uniq)
        except Exception as e:
            if len(live) > 1:   # pad overflow on a merged batch: split it
                for p in live:
                    self._execute([p], seq)
                return
            self._finish(live[0], STATUS_TOO_LARGE, seq=seq, detail=str(e))
            return
        if plan and plan.serve_poison(seq):
            self._inject_poison(sg, seq)

        batch = jax.device_put(hb)
        hg = np.asarray(sg.halo_gids)
        hm = np.asarray(sg.halo_mask)
        store_rows = None
        if cfg.verify_rows and cfg.force_mode is None:
            store_rows = np.asarray(jax.device_get(self._store.h[:, hg]))
        mode, reason, bad = self._policy.decide(seq, hg, hm, store_rows)

        # ---- bounded retry loop: worker crashes and transient failures
        # retry in place; non-finite exact output trips the breaker and
        # re-runs the same batch on the store-free rung
        attempts = 0
        switched = False
        out = new_store = None
        while True:
            attempts += 1
            try:
                if plan:
                    plan.serve_crash_hook(seq)
                step = self._steps[MODE_EXACT if mode == MODE_EXACT
                                   else MODE_TI]
                logits, new_store = step(self.params, self._store, batch,
                                         self._x, self._self_w)
                out = np.asarray(logits)
                if not np.isfinite(out[:sg.n_batch_real]).all():
                    raise _NonFinite()
                break
            except ServeWorkerFault as e:
                self._count("worker_restarts")
                self._event("worker-crash", seq, str(e))
                if attempts >= cfg.max_attempts:
                    for p in live:
                        self._finish(p, STATUS_ERROR, seq=seq,
                                     attempts=attempts,
                                     detail=f"retry budget exhausted: {e}")
                    return
                time.sleep(cfg.backoff_s)
            except _NonFinite:
                if mode == MODE_EXACT and not switched:
                    self._breaker.record_failure(seq)
                    self._event("breaker-open", seq,
                                "non-finite exact output")
                    mode, reason, switched = MODE_TI, "nan-circuit", True
                    nan_gids = self._nonfinite_store_rows(hg, hm)
                    if nan_gids.size:
                        bad = np.union1d(bad, nan_gids)
                else:
                    for p in live:
                        self._finish(p, STATUS_ERROR, seq=seq,
                                     attempts=attempts,
                                     detail="non-finite output on the "
                                            "store-free path")
                    return
            except Exception as e:
                if attempts >= cfg.max_attempts:
                    for p in live:
                        self._finish(p, STATUS_ERROR, seq=seq,
                                     attempts=attempts,
                                     detail=f"execution failed: {e!r}")
                    return
                time.sleep(cfg.backoff_s)

        # ---- commit (exact path refreshes rows, so they are provably fresh:
        # re-record crcs, zero staleness) and breaker bookkeeping
        if mode == MODE_EXACT:
            bg = np.asarray(sg.batch_gids)[:sg.n_batch_real]
            with self._mu:
                self._store = new_store
                self._integrity.record(
                    bg, np.asarray(jax.device_get(new_store.h[:, bg])))
                self._guard.staleness[:, bg] = 0
            was = self._breaker.state
            self._breaker.record_success()
            if was == "half-open" and self._breaker.state == "closed":
                self._event("breaker-closed", seq, "healed")
        elif reason:
            self._event("degraded", seq, reason)

        # ---- respond
        preds = np.argmax(out[:sg.n_batch_real], axis=-1)
        status = STATUS_OK if mode == MODE_EXACT else STATUS_DEGRADED
        now = time.time()
        off = 0
        for p in live:
            k = p.nodes.shape[0]
            idx = inv[off:off + k]
            off += k
            if p.deadline is not None and now > p.deadline:
                self._finish(p, STATUS_TIMEOUT, seq=seq, attempts=attempts,
                             detail="deadline expired during execution")
                continue
            self._finish(
                p, status, classes=preds[idx],
                logits=out[:sg.n_batch_real][idx] if cfg.return_logits
                else None,
                mode=mode, reason=reason, attempts=attempts, seq=seq)

        # ---- post-response repair: heal the rows that forced degradation
        if mode == MODE_TI and bad.size and cfg.repair:
            self._repair(bad, seq)

    def _inject_poison(self, sg, seq: int) -> None:
        """FaultPlan serve-poison drill: NaN store rows the batch will read."""
        hg = np.asarray(sg.halo_gids)[:sg.n_halo_real]
        if hg.size == 0:
            self._event("poisoned", seq, "no halo rows to poison; skipped")
            return
        gids = hg[:min(2, hg.size)]
        with self._mu:
            self._store = self._store._replace(
                h=self._store.h.at[:, jnp.asarray(gids)].set(jnp.nan))
        self._event("poisoned", seq, f"rows {gids.tolist()}")

    def _nonfinite_store_rows(self, hg: np.ndarray,
                              hm: np.ndarray) -> np.ndarray:
        gids = hg[hm > 0]
        if gids.size == 0:
            return np.zeros(0, dtype=np.int64)
        rows = np.asarray(jax.device_get(self._store.h[:, gids]))
        return gids[~np.isfinite(rows).all(axis=(0, 2))].astype(np.int64)

    def _repair(self, gids: np.ndarray, seq: int) -> None:
        """Recompute store rows via the store-free path and write them back.

        Repaired rows are ti-grade (their halo inputs are α-estimates); the
        next exact serve of those nodes overwrites them with exact values.
        The point is liveness: corruption and budget violations are healed,
        not served around forever.
        """
        gids = np.unique(np.asarray(gids, dtype=np.int64))
        if gids.size == 0:
            return
        self._count("repaired_rows", int(gids.size))
        self._event("repair", seq, f"{gids.size} rows")
        cap = self.gateway.max_targets
        for chunk in np.array_split(gids, -(-gids.size // cap)):
            sg, hb = self.gateway.build(chunk)
            batch = jax.device_put(hb)
            _, new_store = self._steps["repair"](
                self.params, self._store, batch, self._x, self._self_w)
            bg = np.asarray(sg.batch_gids)[:sg.n_batch_real]
            with self._mu:
                self._store = new_store
                self._integrity.record(
                    bg, np.asarray(jax.device_get(new_store.h[:, bg])))
                self._guard.staleness[:, bg] = 0
