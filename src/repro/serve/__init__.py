"""Fault-tolerant batched GNN inference over the LMC historical store.

  types.py   — ServeRequest/ServeResponse + the typed error ladder
  gateway.py — arbitrary target sets -> fixed-shape bucket batches
  policy.py  — ServeConfig, degradation ladder (breaker / ρ-staleness / crc)
  server.py  — GNNServer: admission queue, batcher, worker loop, repair

See DESIGN.md §12; quickstart: ``examples/serve_gnn.py``.
"""
from repro.serve.gateway import StoreGateway, request_pads
from repro.serve.policy import (MODE_EXACT, MODE_TI, CircuitBreaker,
                                DegradationPolicy, ServeConfig, StoreIntegrity)
from repro.serve.server import GNNServer, warm_store
from repro.serve.types import (STATUS_CLOSED, STATUS_DEGRADED, STATUS_ERROR,
                               STATUS_OK, STATUS_OVERLOADED, STATUS_TIMEOUT,
                               STATUS_TOO_LARGE, DeadlineExceeded, Overloaded,
                               RequestTooLarge, ServeError, ServeRequest,
                               ServeResponse, ServerClosed)

__all__ = [
    "GNNServer", "warm_store", "StoreGateway", "request_pads",
    "ServeConfig", "DegradationPolicy", "CircuitBreaker", "StoreIntegrity",
    "MODE_EXACT", "MODE_TI",
    "ServeRequest", "ServeResponse", "ServeError", "Overloaded",
    "DeadlineExceeded", "RequestTooLarge", "ServerClosed",
    "STATUS_OK", "STATUS_DEGRADED", "STATUS_OVERLOADED", "STATUS_TIMEOUT",
    "STATUS_TOO_LARGE", "STATUS_CLOSED", "STATUS_ERROR",
]
