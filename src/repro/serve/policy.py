"""Serving degradation policy: when to stop trusting the historical store.

The degradation ladder (DESIGN.md §12) has exactly two rungs:

  exact — halo rows gathered from the historical store; with an exact store
          this answers identically to the full-graph forward.
  ti    — the store-free message-invariance estimate (DESIGN.md §11);
          bounded bias, zero store reads, immune to store corruption.

Three independent detectors can drop a batch one rung, checked in order:

  1. :class:`CircuitBreaker` — trips open on NaN/Inf *output* of the exact
     path, serves ti-only for a cooldown, then probes exact again and closes
     after ``heal_after`` consecutive clean probes.
  2. ρ-staleness — per-row store-staleness counters (the same
     ``HealthGuard.staleness`` accounting the trainer uses) against the one
     shared budget ``repro.core.methods.RHO_BUDGET_DEFAULT``; rows past the
     budget are outside Thm 2's bias bound and cannot be served as "exact".
  3. :class:`StoreIntegrity` — per-row crc32 ledger in the checkpoint
     manifest idiom (``repro.checkpoint.crc32_array``); a cached row whose
     bytes changed without a recorded refresh is corrupt.

Detection is separated from recovery: the policy only *decides*; the server
answers from ti and schedules the offending rows for repair (a store-free
recompute that overwrites them).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.checkpoint import crc32_array
from repro.core.methods import RHO_BUDGET_DEFAULT

MODE_EXACT = "exact"
MODE_TI = "ti"


@dataclasses.dataclass
class ServeConfig:
    """Knobs for :class:`repro.serve.GNNServer`.

    Attributes:
        buckets: target-count pad buckets; each gets one compiled trace.
        queue_depth: admission-queue bound; a full queue sheds with a typed
            Overloaded response instead of blocking the caller.
        batch_window_s: how long the batcher waits to coalesce queued
            requests into one bucket batch (0 = no coalescing delay).
        default_deadline_s: per-request deadline when the request names none.
        max_attempts: bounded retry budget per batch for transient failures
            (worker crash, unexpected exceptions).
        backoff_s: sleep between retry attempts.
        rho_budget: staleness budget (steps) for store rows read by the
            exact path — the shared Thm-2 constant from core/methods.py.
        verify_rows: crc-verify the store rows a batch is about to read
            (detection rung 3); disable to lean on the NaN breaker only.
        repair: recompute over-budget/corrupt rows via the store-free path
            and write them back (heals the store instead of degrading
            forever).
        breaker_heal_after: consecutive clean exact probes that close a
            tripped circuit breaker.
        breaker_cooldown: batches served ti-only before the first probe.
        backend: aggregation backend for the serving forward ("segment" |
            "ell" — the bucketed Pallas SpMM); degradation swaps the
            *compensation*, never the aggregation, so both modes share the
            compiled trace.
        stream: ell-backend streamed-DMA store gather (None = autodetect).
        ti_fwd_mode: Eq.-9 mode of the degraded path ("lmc" blends the α
            estimate with β·fresh — the PR 9 estimator; "historical" serves
            the raw α ⊙ fresh invariance transform).
        force_mode: pin every batch to one rung ("exact" | "ti"); bench and
            debugging only — bypasses all three detectors.
        return_logits: attach raw logits to responses (off: argmax only).
        ell_buckets: row-capacity buckets of the ELL layout (backend="ell").
        warmup: trace every (bucket, mode) pair at server start so no
            request pays jit compilation latency (seconds on CPU; servers
            that care about p99 want it, throwaway test servers don't).
    """

    buckets: tuple = (8, 32, 128)
    queue_depth: int = 64
    batch_window_s: float = 0.002
    default_deadline_s: float = 2.0
    max_attempts: int = 2
    backoff_s: float = 0.02
    rho_budget: int = RHO_BUDGET_DEFAULT
    verify_rows: bool = True
    repair: bool = True
    breaker_heal_after: int = 3
    breaker_cooldown: int = 2
    backend: str = "segment"
    stream: Optional[bool] = None
    ti_fwd_mode: str = "lmc"
    force_mode: Optional[str] = None
    return_logits: bool = False
    ell_buckets: tuple = (8, 32, 128)
    warmup: bool = False

    def validate(self) -> None:
        """Fail fast on out-of-range knobs."""
        if not self.buckets or list(self.buckets) != sorted(set(self.buckets)):
            raise ValueError(f"buckets must be sorted unique: {self.buckets}")
        if self.queue_depth < 1:
            raise ValueError("queue_depth must be >= 1")
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if self.backend not in ("segment", "ell"):
            raise ValueError(f"unknown serving backend {self.backend!r}")
        if self.ti_fwd_mode not in ("lmc", "historical"):
            raise ValueError(f"unknown ti_fwd_mode {self.ti_fwd_mode!r}")
        if self.force_mode not in (None, MODE_EXACT, MODE_TI):
            raise ValueError(f"unknown force_mode {self.force_mode!r}")
        if self.rho_budget < 1:
            raise ValueError("rho_budget must be >= 1")


class CircuitBreaker:
    """NaN/Inf-output circuit breaker over the exact serving path.

    closed --(non-finite exact output)--> open --(cooldown batches)-->
    half-open --(heal_after clean probes)--> closed; any failure while
    probing re-opens. State transitions are driven by the server's batch
    sequence numbers, so "cooldown" is measured in served batches.
    """

    def __init__(self, heal_after: int = 3, cooldown: int = 2):
        self.heal_after = int(heal_after)
        self.cooldown = int(cooldown)
        self._state = "closed"
        self._opened_at = -1
        self._clean = 0

    @property
    def state(self) -> str:
        """"closed" | "open" | "half-open"."""
        return self._state

    def allow_exact(self, seq: int) -> bool:
        """Whether batch ``seq`` may try the exact path (probes included)."""
        if self._state == "closed":
            return True
        if seq - self._opened_at <= self.cooldown:
            return False
        self._state = "half-open"
        return True

    def record_failure(self, seq: int) -> None:
        """Exact path produced non-finite output at batch ``seq``: trip."""
        self._state = "open"
        self._opened_at = seq
        self._clean = 0

    def record_success(self) -> None:
        """A clean exact batch; closes the breaker after ``heal_after``
        consecutive clean probes."""
        if self._state == "half-open":
            self._clean += 1
            if self._clean >= self.heal_after:
                self._state = "closed"
                self._clean = 0


class StoreIntegrity:
    """Per-row crc32 ledger over the serving store's embedding cache.

    The checkpoint manifest idiom (checkpoint/manager.py) applied at row
    granularity: every legitimate write records ``crc32_array`` of the row's
    bytes, and ``verify`` flags rows whose bytes no longer match — bitrot or
    out-of-band writes the serving tier must not trust.
    """

    def __init__(self, num_layers: int, num_nodes: int):
        self._crc = np.zeros((num_layers, num_nodes), dtype=np.uint32)

    def record(self, gids: np.ndarray, rows: np.ndarray) -> None:
        """Record crcs for store rows: ``rows[l, j]`` is (layer l, gids[j])."""
        gids = np.asarray(gids)
        for l in range(rows.shape[0]):
            for j, g in enumerate(gids):
                self._crc[l, g] = crc32_array(rows[l, j])

    def verify(self, gids: np.ndarray, rows: np.ndarray) -> np.ndarray:
        """Gids (subset of ``gids``) whose current bytes mismatch the ledger."""
        gids = np.asarray(gids)
        bad = np.zeros(gids.shape[0], dtype=bool)
        for l in range(rows.shape[0]):
            for j, g in enumerate(gids):
                if self._crc[l, g] != crc32_array(rows[l, j]):
                    bad[j] = True
        return gids[bad]


class DegradationPolicy:
    """Per-batch mode decision: exact unless a detector says otherwise.

    Returns ``(mode, reason, bad_gids)`` where ``bad_gids`` (possibly empty)
    are the store rows to schedule for repair. Pure decision logic — the
    server owns all mutation (store commits, repairs, breaker bookkeeping).
    """

    def __init__(self, config: ServeConfig, guard, integrity: StoreIntegrity,
                 breaker: CircuitBreaker):
        self.config = config
        self.guard = guard          # HealthGuard: shares trainer accounting
        self.integrity = integrity
        self.breaker = breaker

    def decide(self, seq: int, halo_gids: np.ndarray, halo_mask: np.ndarray,
               store_rows: Optional[np.ndarray]
               ) -> tuple[str, Optional[str], np.ndarray]:
        """Pick the rung for batch ``seq`` reading the given store rows.

        ``store_rows`` is the host copy of ``store.h[:, halo_gids]`` (None
        skips the crc/finite checks, e.g. when ``verify_rows`` is off).
        """
        cfg = self.config
        none = np.zeros(0, dtype=np.int64)
        if cfg.force_mode is not None:
            return cfg.force_mode, "forced", none
        if not self.breaker.allow_exact(seq):
            return MODE_TI, "nan-circuit-open", none
        gids = np.asarray(halo_gids)[np.asarray(halo_mask) > 0]
        if gids.size == 0:
            return MODE_EXACT, None, none
        stale = self.guard.staleness[:, gids].max(axis=0) > cfg.rho_budget
        if stale.any():
            worst = int(self.guard.staleness[:, gids].max())
            return (MODE_TI,
                    f"staleness {worst} > rho budget {cfg.rho_budget}",
                    gids[stale].astype(np.int64))
        if cfg.verify_rows and store_rows is not None:
            k = gids.size
            corrupt = self.integrity.verify(gids, store_rows[:, :k])
            if corrupt.size:
                return (MODE_TI, f"store-corrupt ({corrupt.size} rows)",
                        corrupt.astype(np.int64))
        return MODE_EXACT, None, none
