"""Serving request/response types and the typed error ladder (DESIGN.md §12).

Every admission outcome is a :class:`ServeResponse` with a machine-readable
``status`` — the server never raises across the submit boundary and never
drops a request silently. The exception classes exist for callers that prefer
control flow over status inspection (``ServeResponse.raise_for_status``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

# response status values, in degradation-ladder order
STATUS_OK = "ok"                  # exact path (historical store) answered
STATUS_DEGRADED = "degraded"      # store-free ti path answered (see reason)
STATUS_OVERLOADED = "overloaded"  # shed at admission: queue full
STATUS_TIMEOUT = "timeout"        # deadline expired before an answer
STATUS_TOO_LARGE = "too-large"    # request exceeds the largest pad bucket
STATUS_CLOSED = "closed"          # server was shut down without drain
STATUS_ERROR = "error"            # retries exhausted on a hard failure


class ServeError(RuntimeError):
    """Base class of the serving tier's typed failures."""


class Overloaded(ServeError):
    """Admission queue full — the request was shed, not queued."""


class DeadlineExceeded(ServeError):
    """The per-request deadline expired before a response was produced."""


class RequestTooLarge(ServeError):
    """More target nodes than the largest configured pad bucket."""


class ServerClosed(ServeError):
    """Submitted to (or abandoned by) a server that is shutting down."""


_STATUS_ERRORS = {
    STATUS_OVERLOADED: Overloaded,
    STATUS_TIMEOUT: DeadlineExceeded,
    STATUS_TOO_LARGE: RequestTooLarge,
    STATUS_CLOSED: ServerClosed,
    STATUS_ERROR: ServeError,
}


@dataclasses.dataclass
class ServeRequest:
    """One inference request: predict classes for ``nodes`` (global ids).

    ``deadline_s`` is a relative budget from submission; ``None`` uses the
    server's ``ServeConfig.default_deadline_s``.
    """

    nodes: np.ndarray
    request_id: str = ""
    deadline_s: Optional[float] = None


@dataclasses.dataclass
class ServeResponse:
    """Outcome of one request; always produced, whatever happened.

    ``classes`` aligns with the request's ``nodes`` (argmax logits); ``mode``
    records which rung of the degradation ladder answered ("exact" — the
    historical-store path — or "ti" — the store-free message-invariance
    path), and ``degraded_reason`` says why the ladder dropped a rung
    (staleness budget, crc mismatch, NaN circuit breaker, ...).
    """

    request_id: str
    status: str
    classes: Optional[np.ndarray] = None
    logits: Optional[np.ndarray] = None
    mode: Optional[str] = None
    degraded_reason: Optional[str] = None
    latency_s: float = 0.0
    attempts: int = 0
    batch_seq: int = -1
    detail: str = ""

    @property
    def ok(self) -> bool:
        """True iff the request was answered (exact or degraded)."""
        return self.status in (STATUS_OK, STATUS_DEGRADED)

    def raise_for_status(self) -> "ServeResponse":
        """Raise the matching typed error for non-answer statuses."""
        if not self.ok:
            err = _STATUS_ERRORS.get(self.status, ServeError)
            raise err(f"request {self.request_id or '<anon>'}: "
                      f"{self.status} {self.detail}".rstrip())
        return self
