"""Store gateway: pad arbitrary target-node requests into bucket batches.

Serving requests name arbitrary target sets, unlike training batches which
come from the cluster sampler's partition. The gateway reuses the exact
training-batch machinery — ``build_subgraph`` (graph/structure.py) with
``num_parts=1, clusters_in_batch=1`` builds the 1-hop padded extension, and
``host_batch`` (core/lmc.py) re-buckets it into the Pallas ELL layout — but
with *request-bucket* pad shapes instead of sampler-epoch maxima: target
counts are rounded up to one of a few capacities so every batch hits one of
``len(buckets)`` compiled traces (the serving analogue of serve_decode.py's
prefill buckets).

Pad sizes per bucket are worst-case by degree order: any ``b`` targets pull
at most ``sum(top-b degrees)`` halo nodes, and the subgraph's edges (into
batch rows + into halo rows from the extended set) are a subset of the
directed edge set, so the bounds below make ``build_subgraph`` overflow
impossible for in-range requests; the server still turns a (would-be-bug)
overflow into a typed response rather than a crash.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.lmc import Batch, host_batch
from repro.graph.structure import Graph, PaddedSubgraph, build_subgraph
from repro.serve.types import RequestTooLarge


def _round_up(x: int, mult: int) -> int:
    return max(mult, ((int(x) + mult - 1) // mult) * mult)


def request_pads(graph: Graph, bucket: int, *,
                 degrees: Optional[np.ndarray] = None,
                 halo_round: int = 64,
                 edge_round: int = 256) -> tuple[int, int]:
    """Worst-case ``(pad_halo, pad_edges)`` for any ``bucket`` target nodes."""
    if degrees is None:
        degrees = graph.degrees()
    deg_desc = np.sort(degrees)[::-1]
    n, ne = graph.num_nodes, graph.num_edges
    # any b targets have <= sum(top-b degrees) distinct neighbors
    halo_max = int(min(n, deg_desc[:bucket].sum()))
    pad_halo = min(_round_up(halo_max, halo_round), _round_up(n, halo_round))
    # e1 (into batch rows) <= sum(top-b degrees); e2 (into halo rows) <= sum
    # of the halo nodes' degrees; both are disjoint subsets of the directed
    # edge set, so the total never exceeds num_edges
    edge_max = int(min(ne, deg_desc[:bucket].sum()
                       + deg_desc[:pad_halo].sum()))
    pad_edges = min(_round_up(edge_max, edge_round), _round_up(ne, edge_round))
    return pad_halo, pad_edges


class StoreGateway:
    """Builds fixed-shape host batches for arbitrary target-node sets.

    ``agg_backend`` selects the aggregation path the batches are built for
    ("segment" | "ell"); every batch additionally carries ``ti_scale`` so the
    server can swap compensation to the store-free ti path without changing
    the batch (or the compiled trace shape).
    """

    def __init__(self, graph: Graph, *, buckets=(8, 32, 128),
                 agg_backend: str = "segment", ell_buckets=(8, 32, 128)):
        assert agg_backend in ("segment", "ell"), agg_backend
        self.graph = graph
        self.buckets = tuple(sorted(int(b) for b in buckets))
        self.agg_backend = agg_backend
        self.ell_buckets = tuple(ell_buckets)
        self.degrees = graph.degrees()
        self.pads = {b: request_pads(graph, b, degrees=self.degrees)
                     for b in self.buckets}

    @property
    def max_targets(self) -> int:
        """Largest admissible target count (the top bucket's capacity)."""
        return self.buckets[-1]

    def bucket_for(self, n_targets: int) -> int:
        """Smallest bucket holding ``n_targets`` targets."""
        for b in self.buckets:
            if n_targets <= b:
                return b
        raise RequestTooLarge(
            f"{n_targets} target nodes exceed the largest pad bucket "
            f"({self.buckets[-1]})")

    def build(self, targets: np.ndarray) -> tuple[PaddedSubgraph, Batch]:
        """Padded subgraph + host Batch for unique target node ids."""
        targets = np.asarray(targets, dtype=np.int64)
        bucket = self.bucket_for(targets.shape[0])
        pad_halo, pad_edges = self.pads[bucket]
        sg = build_subgraph(
            self.graph, targets, pad_batch=bucket, pad_halo=pad_halo,
            pad_edges=pad_edges, num_parts=1, clusters_in_batch=1,
            degrees=self.degrees)
        # "ti" host batches are "ell" batches + the α scales; "segment"
        # batches get the scales attached directly — either way the ti
        # compensation path needs no rebuild
        kind = "ti" if self.agg_backend == "ell" else "segment"
        hb = host_batch(sg, backend=kind, ell_buckets=self.ell_buckets)
        if hb.ti_scale is None:
            hb = hb._replace(ti_scale=np.asarray(sg.ti_scale))
        return sg, hb
