"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never touches
jax device state (required so smoke tests see 1 device while the dry-run sees
512 placeholder devices via XLA_FLAGS).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(shape: tuple, axes: tuple) -> jax.sharding.Mesh:
    """Arbitrary mesh (tests, elastic re-scale)."""
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def data_axes(mesh: jax.sharding.Mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
