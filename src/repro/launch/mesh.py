"""Production mesh construction — thin façade over :mod:`repro.dist.mesh`.

A FUNCTION, not a module-level constant — importing this module never touches
jax device state (required so smoke tests see 1 device while the dry-run sees
512 placeholder devices via XLA_FLAGS). Kept as the launcher-facing import
path; the implementation (and jax version compatibility) lives in
`repro.dist.mesh`, and axis bookkeeping in `repro.dist.sharding`.
"""
from __future__ import annotations

from repro.dist.mesh import make_mesh, make_production_mesh
from repro.dist.sharding import data_axes

__all__ = ["make_mesh", "make_production_mesh", "data_axes"]
