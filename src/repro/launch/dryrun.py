import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile EVERY (arch × shape × mesh) cell.

For each cell this prints/records
  * ``compiled.memory_analysis()``  — proves the step fits per-device HBM,
  * ``compiled.cost_analysis()``    — HLO FLOPs / bytes for §Roofline,
  * parsed per-device collective bytes from the optimized (SPMD) HLO.

Run:
  PYTHONPATH=src python -m repro.launch.dryrun                    # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --multi-pod --gnn  # incl. GNN step

Results land in experiments/dryrun/*.json (one file per cell).
"""
import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

COLLECTIVE_RE = re.compile(
    r"^\s*(?:%\S+ = )?"
    r"(?:\(?([a-z0-9\[\],{}\s]*?)\)?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.MULTILINE)
SHAPE_RE = re.compile(r"(f32|bf16|f16|s32|u32|s8|u8|pred|f64|s64|c64)\[([\d,]*)\]")
DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
               "u8": 1, "pred": 1, "f64": 8, "s64": 8, "c64": 8}


def cost_analysis_dict(compiled) -> dict:
    """`Compiled.cost_analysis()` returns a dict on new jax but a
    one-element list of dicts on jax 0.4.x — normalize to the dict."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes moved by collectives, from the SPMD module text.

    Shapes in the per-device module are shard-local, so the summed output
    bytes approximate per-device received bytes. Ops inside while-loop
    (scan) bodies are counted once — the roofline harness extrapolates
    per-layer costs from unrolled builds instead (see benchmarks/roofline.py).
    """
    per_kind: dict[str, float] = {}
    count = 0
    for line in hlo_text.splitlines():
        m = re.search(r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                      r"collective-permute)(?:-start)?\(", line)
        if not m or "= " not in line:
            continue
        kind = m.group(1)
        lhs = line.split("= ")[0]
        shapes = SHAPE_RE.findall(line.split("= ")[1].split("(")[0])
        nbytes = 0
        for dt, dims in shapes:
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * DTYPE_BYTES[dt]
        if nbytes:
            per_kind[kind] = per_kind.get(kind, 0) + nbytes
            count += 1
    per_kind["num_ops"] = count
    per_kind["total"] = sum(v for k, v in per_kind.items()
                            if k not in ("num_ops",))
    return per_kind


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             opt_level: str | None = None, verbose: bool = True) -> dict:
    from repro.configs import SHAPES, applicable_shapes, get_config
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_cell

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape_name not in applicable_shapes(cfg):
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skipped",
                "reason": ("no decoder" if shape.kind == "decode" and
                           not cfg.has_decoder else
                           "full-attention arch: long_500k requires "
                           "sub-quadratic attention (DESIGN.md §5)")}

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    lm, step, args, shs = build_cell(cfg, shape, mesh)
    # donate the state the production step donates: params+opt (train) or the
    # KV caches (decode) — memory_analysis then reports the aliased peak
    donate = {"train": (0, 1), "decode": (1,), "prefill": ()}[shape.kind]
    with mesh:
        lowered = jax.jit(step, in_shardings=shs,
                          donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        copts = {}
        if opt_level is not None:
            copts["xla_backend_optimization_level"] = opt_level
        compiled = lowered.compile(compiler_options=copts or None)
        t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = cost_analysis_dict(compiled)
    coll = collective_bytes(compiled.as_text())
    res = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "multi_pod": multi_pod, "status": "ok",
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops": ca.get("flops"), "bytes_accessed": ca.get("bytes accessed"),
        "collectives": coll,
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_per_device_gb": round(
                (ma.argument_size_in_bytes + ma.output_size_in_bytes
                 + ma.temp_size_in_bytes - ma.alias_size_in_bytes) / 2**30, 3),
        },
    }
    if verbose:
        print(f"[{res['mesh']}] {arch} × {shape_name}: "
              f"compile {t_compile:.1f}s, "
              f"args {ma.argument_size_in_bytes/2**30:.2f} GiB/dev, "
              f"temp {ma.temp_size_in_bytes/2**30:.2f} GiB/dev, "
              f"collective ops {coll.get('num_ops', 0)}", flush=True)
        print("  memory_analysis:", ma, flush=True)
        print("  cost_analysis: flops=%.3e bytes=%.3e" %
              (ca.get("flops", 0), ca.get("bytes accessed", 0)), flush=True)
    return res


def run_gnn_cell(*, multi_pod: bool, verbose: bool = True) -> dict:
    """Dry-run the paper's own workload: the distributed LMC train step
    (one cluster per data-parallel device, halo compensation via the sharded
    historical stores)."""
    from repro.core import make_train_step, LMC
    from repro.core.distributed import spmd_shardings
    from repro.core.lmc import Batch
    from repro.launch.mesh import make_production_mesh
    from repro.models import make_gnn
    import jax.numpy as jnp

    from repro.dist.sharding import dp_axis_size

    mesh = make_production_mesh(multi_pod=multi_pod)
    ndp = dp_axis_size(mesh)
    # production-scale synthetic stand-in: 16M nodes, d=512 GCNII
    n_nodes = 16 * 2**20
    d, dx, L, ncls = 512, 512, 4, 64
    per_dev_batch, per_dev_halo, per_dev_edges = 4096, 8192, 262144
    nb, nh, ne = per_dev_batch * ndp, per_dev_halo * ndp, per_dev_edges * ndp

    gnn = make_gnn("gcnii", dx, d, ncls, L)
    step = make_train_step(gnn, LMC, n_nodes)

    f32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.float32)
    i32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.int32)
    batch_abs = Batch(
        batch_gids=i32(nb), halo_gids=i32(nh), batch_mask=f32(nb),
        halo_mask=f32(nh), edge_src=i32(ne), edge_dst=i32(ne), edge_w=f32(ne),
        labels=i32(nb + nh), labeled_mask=f32(nb + nh), beta=f32(nh),
        loss_scale=f32(), grad_scale=f32())
    store_abs = type("HS", (), {})
    from repro.core.history import HistoricalState
    store_abs = HistoricalState(h=f32(L, n_nodes, d), v=f32(L - 1, n_nodes, d))
    x_abs, sw_abs = f32(n_nodes, dx), f32(n_nodes)

    batch_sh, store_sh, x_sh, sw_sh, param_sh = spmd_shardings(mesh)
    params_abs = jax.eval_shape(lambda k: gnn.init_params(k), jax.random.key(0))
    params_sh = jax.tree.map(lambda _: param_sh, params_abs,
                             is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    store_sh_t = HistoricalState(h=store_sh["h"], v=store_sh["v"])

    t0 = time.time()
    with mesh:
        # donate the historical stores: the production trainer updates them
        # in place (H̄/V̄ are step-local state, §Perf GNN iteration)
        lowered = jax.jit(step, in_shardings=(params_sh, store_sh_t, batch_sh,
                                              x_sh, sw_sh),
                          donate_argnums=(1,)).lower(
            params_abs, store_abs, batch_abs, x_abs, sw_abs)
        compiled = lowered.compile()
    t_compile = time.time() - t0
    ma = compiled.memory_analysis()
    ca = cost_analysis_dict(compiled)
    coll = collective_bytes(compiled.as_text())
    res = {
        "arch": "gnn-lmc-gcnii", "shape": f"n{n_nodes}_d{d}_L{L}",
        "mesh": "2x16x16" if multi_pod else "16x16", "multi_pod": multi_pod,
        "status": "ok", "compile_s": round(t_compile, 1),
        "flops": ca.get("flops"), "bytes_accessed": ca.get("bytes accessed"),
        "collectives": coll,
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "peak_per_device_gb": round(
                (ma.argument_size_in_bytes + ma.output_size_in_bytes
                 + ma.temp_size_in_bytes) / 2**30 / len(jax.devices()) * 1, 3),
        },
    }
    if verbose:
        print(f"[GNN {res['mesh']}] LMC distributed step: compile "
              f"{t_compile:.1f}s, collectives {coll}", flush=True)
        print("  memory_analysis:", ma, flush=True)
    return res


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--multi-pod", action="store_true",
                    help="only the 2x16x16 mesh (default: both meshes)")
    ap.add_argument("--single-pod", action="store_true",
                    help="only the 16x16 mesh")
    ap.add_argument("--gnn", action="store_true",
                    help="also dry-run the distributed GNN-LMC step")
    ap.add_argument("--opt-level", default=None,
                    help="xla_backend_optimization_level override")
    ap.add_argument("--fail-fast", action="store_true")
    args = ap.parse_args()

    from repro.configs import ARCH_NAMES, SHAPES

    OUT_DIR.mkdir(parents=True, exist_ok=True)
    archs = [args.arch] if args.arch else ARCH_NAMES
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True]
    if args.multi_pod:
        meshes = [True]
    if args.single_pod:
        meshes = [False]

    failures = []
    for multi_pod in meshes:
        if args.gnn:
            res = run_gnn_cell(multi_pod=multi_pod)
            tag = f"gnn_lmc_{'2x16x16' if multi_pod else '16x16'}"
            (OUT_DIR / f"{tag}.json").write_text(json.dumps(res, indent=1))
        for arch in archs:
            for shape in shapes:
                tag = (f"{arch}_{shape}_"
                       f"{'2x16x16' if multi_pod else '16x16'}").replace("/", "_")
                try:
                    res = run_cell(arch, shape, multi_pod=multi_pod,
                                   opt_level=args.opt_level)
                except Exception as e:  # noqa: BLE001 - report, keep sweeping
                    res = {"arch": arch, "shape": shape, "multi_pod": multi_pod,
                           "status": "error", "error": repr(e),
                           "trace": traceback.format_exc()[-2000:]}
                    failures.append(tag)
                    print(f"FAIL {tag}: {e!r}", flush=True)
                    if args.fail_fast:
                        (OUT_DIR / f"{tag}.json").write_text(
                            json.dumps(res, indent=1))
                        raise
                (OUT_DIR / f"{tag}.json").write_text(json.dumps(res, indent=1))
    print(f"\ndry-run complete; failures: {failures or 'none'}")


if __name__ == "__main__":
    main()
