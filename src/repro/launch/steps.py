"""Step builders + abstract input specs for every (arch × shape) cell.

Used by the dry-run (ShapeDtypeStructs — no allocation), the trainer and the
examples (real arrays). One code path builds both: `input_specs` returns
(abstract_inputs, shardings) for the jit'd step of the given shape kind.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.dist.sharding import activation_sharding, dp_axis_size, dp_entry
from repro.models.lm import LM
from repro.models.spec import abstract, default_rules, shardings as spec_shardings
from repro.optim.optimizers import Optimizer, make_optimizer


def fsdp_axes_for(cfg: ArchConfig, mesh: Mesh) -> tuple:
    axes = ("data",)
    if cfg.fsdp_over_pod and "pod" in mesh.axis_names:
        axes = ("pod", "data")
    return axes


# ------------------------------------------------------------------ steps
def make_lm_train_step(lm: LM, opt: Optimizer) -> Callable:
    """Full production step: loss -> grads (with optional microbatch
    gradient accumulation) -> clipped optimizer update."""
    n_mb = max(lm.cfg.microbatches, 1)

    def grads_of(params, batch):
        return jax.value_and_grad(lm.train_loss)(params, batch)

    def train_step(params, opt_state, batch):
        if n_mb == 1:
            loss, grads = grads_of(params, batch)
        else:
            mb = jax.tree.map(
                lambda x: x.reshape(n_mb, x.shape[0] // n_mb, *x.shape[1:]),
                batch)

            def body(acc, mb_batch):
                loss_i, g_i = grads_of(params, mb_batch)
                acc = jax.tree.map(
                    lambda a, g: a + g.astype(a.dtype), acc[0], g_i), \
                    acc[1] + loss_i
                return acc, None

            acc_dt = jnp.dtype(lm.cfg.grad_accum_dtype)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dt), params)
            if lm.unroll:
                acc = (zeros, jnp.float32(0.0))
                for i in range(n_mb):
                    mbi = jax.tree.map(lambda x: x[i], mb)
                    acc, _ = body(acc, mbi)
            else:
                acc, _ = jax.lax.scan(body, (zeros, jnp.float32(0.0)), mb)
            grads = jax.tree.map(lambda g: g / n_mb, acc[0])
            loss = acc[1] / n_mb
        new_params, new_state, gnorm = opt.update(grads, opt_state, params,
                                                  opt.lr)
        return new_params, new_state, {"loss": loss, "grad_norm": gnorm}
    return train_step


def make_lm_prefill_step(lm: LM, max_seq: int) -> Callable:
    def prefill_step(params, tokens, memory=None):
        return lm.prefill(params, tokens, max_seq, memory)
    return prefill_step


def make_lm_decode_step(lm: LM) -> Callable:
    def decode_step(params, caches, token, length):
        return lm.decode_step(params, caches, token, length)
    return decode_step


# ------------------------------------------------------------- input specs
def input_specs(cfg: ArchConfig, lm: LM, shape: ShapeConfig, mesh: Mesh,
                opt: Optional[Optimizer] = None):
    """(abstract_args, in_shardings) for the step of `shape.kind`."""
    rules = default_rules(fsdp_axes_for(cfg, mesh))
    pspec_tree = lm.params_spec()
    params_abs = abstract(pspec_tree)
    params_sh = spec_shardings(pspec_tree, rules, mesh)
    dp = dp_entry(mesh)
    B, S = shape.global_batch, shape.seq_len

    def tok_sh(bdim_divisible: bool):
        return NamedSharding(mesh, P(dp if bdim_divisible else None, None))

    b_ok = B % max(dp_axis_size(mesh), 1) == 0

    if shape.kind == "train":
        batch_abs: dict[str, Any] = {
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "loss_mask": jax.ShapeDtypeStruct((B, S), jnp.float32),
        }
        batch_sh: dict[str, Any] = {"tokens": tok_sh(b_ok),
                                    "loss_mask": tok_sh(b_ok)}
        if cfg.family in ("vlm", "encdec"):
            T = cfg.frontend_tokens or S
            batch_abs["memory"] = jax.ShapeDtypeStruct((B, T, cfg.d_model),
                                                       jnp.bfloat16)
            batch_sh["memory"] = NamedSharding(
                mesh, P(dp if b_ok else None, None, None))
        assert opt is not None
        opt_abs = opt.abstract_state(pspec_tree)
        opt_sh = spec_shardings(opt.state_spec(pspec_tree), rules, mesh)
        args = (params_abs, opt_abs, batch_abs)
        shs = (params_sh, opt_sh, batch_sh)
        return args, shs

    if shape.kind == "prefill":
        args = [params_abs, jax.ShapeDtypeStruct((B, S), jnp.int32)]
        shs = [params_sh, tok_sh(b_ok)]
        if cfg.family in ("vlm", "encdec"):
            T = cfg.frontend_tokens or S
            args.append(jax.ShapeDtypeStruct((B, T, cfg.d_model), jnp.bfloat16))
            shs.append(NamedSharding(mesh, P(dp if b_ok else None, None, None)))
        return tuple(args), tuple(shs)

    if shape.kind == "decode":
        cache_spec = lm.cache_spec(B, S)
        caches_abs = abstract(cache_spec)
        caches_sh = spec_shardings(cache_spec, rules, mesh)
        args = (params_abs, caches_abs,
                jax.ShapeDtypeStruct((B, 1), jnp.int32),
                jax.ShapeDtypeStruct((), jnp.int32))
        shs = (params_sh, caches_sh, tok_sh(b_ok), NamedSharding(mesh, P()))
        return args, shs

    raise ValueError(shape.kind)


def _with_act_sharding(fn, mesh):
    def inner(*a, **kw):
        with activation_sharding(mesh):
            return fn(*a, **kw)
    return inner


def build_cell(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh, *,
               depth_profile=None, unroll: bool = False):
    """(step_fn, abstract_args, in_shardings) for one dry-run cell."""
    lm = LM(cfg, depth_profile=depth_profile, unroll=unroll)
    if shape.kind == "train":
        opt = make_optimizer(cfg.optimizer)
        step = make_lm_train_step(lm, opt)
        args, shs = input_specs(cfg, lm, shape, mesh, opt)
    elif shape.kind == "prefill":
        step = make_lm_prefill_step(lm, shape.seq_len)
        args, shs = input_specs(cfg, lm, shape, mesh)
    else:
        step = make_lm_decode_step(lm)
        args, shs = input_specs(cfg, lm, shape, mesh)
    return lm, _with_act_sharding(step, mesh), args, shs
