"""deepseek-coder-33b — [arXiv:2401.14196; hf]
62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256, llama-arch."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-coder-33b", family="dense",
    n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=19200, vocab=32256, head_dim=128,
    rope_theta=100_000.0,
    optimizer="adamw", remat="full", microbatches=4,
)
