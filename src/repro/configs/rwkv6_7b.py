"""rwkv6-7b (Finch) — [arXiv:2404.05892; hf]
32L d_model=4096 (attention-free) d_ff=14336 vocab=65536,
data-dependent decay; head_dim 64. Sub-quadratic -> runs long_500k."""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="rwkv6-7b", family="ssm",
    n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64,
    d_ff=14336, vocab=65536, head_dim=64,
    ssm=SSMConfig(d_state=64, head_dim=64, chunk=128),
    sub_quadratic=True,
    optimizer="adamw", remat="full", microbatches=4,
    notes="wkv6 implemented in chunked matmul form (TPU-native, MXU-aligned)",
)
