"""seamless-m4t-large-v2 — [arXiv:2308.11596; hf]
Enc-dec multimodal backbone: 24L encoder + 24L decoder, d_model=1024 16H
(kv=16) d_ff=8192 vocab=256206. The speech/text frontend is a STUB:
``input_specs()`` provides precomputed frame embeddings (B, S_src, d_model)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2", family="encdec",
    n_layers=48, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab=256206, head_dim=64,
    enc_layers=24, dec_layers=24,
    rope_theta=10_000.0,
    optimizer="adamw", remat="full",
    notes="24L enc + 24L dec backbone; modality frontend stubbed per assignment",
)
