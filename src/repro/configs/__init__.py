"""Config registry: the 10 assigned architectures + the paper's GNN configs."""
from __future__ import annotations

import importlib

from repro.configs.base import (ArchConfig, MLAConfig, MoEConfig, SSMConfig,
                                ShapeConfig, SHAPES, applicable_shapes)

_ARCH_MODULES = {
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "llama-3.2-vision-90b": "llama3_2_vision_90b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "zamba2-1.2b": "zamba2_1_2b",
    "rwkv6-7b": "rwkv6_7b",
    "internlm2-20b": "internlm2_20b",
    "llama3.2-1b": "llama3_2_1b",
    "qwen2.5-32b": "qwen2_5_32b",
    "deepseek-coder-33b": "deepseek_coder_33b",
}

ARCH_NAMES = list(_ARCH_MODULES)


def get_config(name: str) -> ArchConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; options: {ARCH_NAMES}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")
    return mod.CONFIG


def reduced_config(name: str) -> ArchConfig:
    """CPU-runnable smoke config of the same family (small dims, same wiring)."""
    import dataclasses
    cfg = get_config(name)
    kw = dict(
        n_layers=min(cfg.n_layers, 4), d_model=64,
        n_heads=4, n_kv_heads=min(4, max(1, cfg.n_kv_heads * 4 // cfg.n_heads)),
        d_ff=128, vocab=512, head_dim=16, remat="none", attn_chunk=64,
    )
    if cfg.enc_layers:
        kw.update(enc_layers=2, dec_layers=2, n_layers=4)
    if cfg.cross_every:
        kw.update(cross_every=2, frontend_tokens=16)
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, num_experts=8, top_k=2, d_expert=32,
            num_shared=min(cfg.moe.num_shared, 1), dispatch_chunks=2,
            first_dense_layers=min(cfg.moe.first_dense_layers, 1), d_ff_dense=128)
    if cfg.mla is not None:
        kw["mla"] = dataclasses.replace(
            cfg.mla, kv_lora_rank=32, q_lora_rank=(16 if cfg.mla.q_lora_rank else 0),
            rope_head_dim=8, nope_head_dim=16, v_head_dim=16)
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(cfg.ssm, d_state=16, head_dim=16, chunk=16)
    if cfg.attn_every:
        kw["attn_every"] = 2
    if cfg.mtp_depth:
        kw["mtp_depth"] = 1
    return dataclasses.replace(cfg, **kw)


__all__ = ["ArchConfig", "MLAConfig", "MoEConfig", "SSMConfig", "ShapeConfig",
           "SHAPES", "applicable_shapes", "ARCH_NAMES", "get_config",
           "reduced_config"]
