"""zamba2-1.2b — [arXiv:2411.15242; hf]
38L d_model=2048 d_ff=8192 vocab=32000, Mamba2 backbone with a *shared*
attention block applied every 6 Mamba layers (32H, kv=32), ssm_state=64.
Hybrid SSM -> runs long_500k."""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32000, head_dim=64,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, n_groups=1,
                  chunk=128),
    attn_every=6, shared_attn=True,
    sub_quadratic=True,
    optimizer="adamw", remat="full", microbatches=4,
)
