"""llama-3.2-vision-90b — [hf:meta-llama/Llama-3.2-90B-Vision; unverified]
100L total (80 self-attn + 20 cross-attn image layers, one every 5),
d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.
Vision frontend is a STUB: precomputed patch embeddings (B, 1601, d_model)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b", family="vlm",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab=128256, head_dim=128,
    cross_every=5, frontend_tokens=1601,
    rope_theta=500_000.0,
    optimizer="adafactor", remat="full", fsdp_over_pod=True, microbatches=8,
)
