"""deepseek-v2-lite-16b — [arXiv:2405.04434; hf]
27L d_model=2048 16H d_ff(expert)=1408 vocab=102400.
MLA kv_lora=512 (no q compression), MoE: 2 shared + 64 routed, top-6,
first layer dense FFN (10944)."""
from repro.configs.base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=102400,
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=0, rope_head_dim=64,
                  nope_head_dim=128, v_head_dim=128),
    moe=MoEConfig(num_experts=64, top_k=6, d_expert=1408, num_shared=2,
                  capacity_factor=1.25, first_dense_layers=1, d_ff_dense=10944),
    rope_theta=10_000.0,
    optimizer="adamw", remat="full",
)
