"""Architecture + shape configuration dataclasses for the assigned model pool."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int            # routed experts
    top_k: int
    d_expert: int               # per-expert FFN hidden dim
    num_shared: int = 0         # always-on shared experts (DeepSeek)
    capacity_factor: float = 1.25
    first_dense_layers: int = 0  # leading layers with a dense FFN instead
    d_ff_dense: int = 0          # hidden dim of those dense FFNs
    dispatch_chunks: int = 16    # lax.map chunks over token groups (memory cap)


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 0         # 0 = no query compression (V2-Lite)
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 128


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    qkv_bias: bool = False       # Qwen2.5
    rope_theta: float = 500_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # family extensions
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    attn_every: int = 0          # hybrid: one (shared) attention block every k layers
    shared_attn: bool = False    # zamba2: attention block weights are shared
    enc_layers: int = 0          # encdec
    dec_layers: int = 0
    cross_every: int = 0         # vlm: cross-attention layer every k layers
    frontend_tokens: int = 0     # vlm/audio: stub frontend sequence length
    mtp_depth: int = 0           # DeepSeek-V3 multi-token prediction heads
    sub_quadratic: bool = False  # supports long_500k
    has_decoder: bool = True
    # training-system knobs
    optimizer: str = "adamw"     # adamw | adafactor | adamw8bit
    remat: str = "full"          # full | dots | none
    microbatches: int = 1        # gradient-accumulation steps per train step
    grad_accum_dtype: str = "float32"  # bf16 halves the accumulator (671B cfg)
    fsdp_over_pod: bool = True   # shard params over the pod axis too
    attn_chunk: int = 1024       # flash-style KV/Q chunking threshold block
    notes: str = ""

    @property
    def dh(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def param_count(self) -> int:
        """Approximate parameter count (reported vs public figures in configs)."""
        d, L, V = self.d_model, self.n_layers, self.vocab
        dh = self.dh
        emb = V * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.mla is not None:
            m = self.mla
            qdim = self.n_heads * (m.nope_head_dim + m.rope_head_dim)
            if m.q_lora_rank:
                per_layer += d * m.q_lora_rank + m.q_lora_rank * qdim
            else:
                per_layer += d * qdim
            per_layer += d * (m.kv_lora_rank + m.rope_head_dim)
            per_layer += m.kv_lora_rank * self.n_heads * (m.nope_head_dim + m.v_head_dim)
            per_layer += self.n_heads * m.v_head_dim * d
        elif self.family in ("dense", "moe", "vlm", "encdec", "hybrid"):
            per_layer += d * self.n_heads * dh + 2 * d * self.n_kv_heads * dh \
                + self.n_heads * dh * d
        if self.moe is not None:
            mo = self.moe
            expert = 3 * d * mo.d_expert
            moe_layers = L - mo.first_dense_layers
            per_layer_moe = (mo.num_experts + mo.num_shared) * expert + d * mo.num_experts
            total_ffn = moe_layers * per_layer_moe \
                + mo.first_dense_layers * 3 * d * mo.d_ff_dense
        elif self.family == "ssm":
            total_ffn = L * 2 * d * self.d_ff  # rwkv channel-mix (2 mats)
        else:
            total_ffn = L * 3 * d * self.d_ff  # swiglu
        if self.family == "ssm":
            # rwkv6 time-mix: r,k,v,g,o (d×d) + decay/ln params
            per_layer = 5 * d * d + 2 * d * 64
        if self.family == "hybrid" and self.ssm is not None:
            d_in = self.ssm.expand * d
            per_layer = 2 * d * d_in + d_in * d + d_in * (2 * self.ssm.d_state)
        return emb + L * per_layer + total_ffn


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str                # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


def applicable_shapes(cfg: ArchConfig) -> list[str]:
    out = ["train_4k", "prefill_32k"]
    if cfg.has_decoder:
        out.append("decode_32k")
        if cfg.sub_quadratic:
            out.append("long_500k")
    return out
