"""deepseek-v3-671b — [arXiv:2412.19437; hf]
61L d_model=7168 128H d_ff(expert)=2048 vocab=129280.
MLA (kv_lora=512, q_lora=1536), MoE: 1 shared + 256 routed top-8,
first 3 layers dense FFN (18432), 1 MTP module.

Memory note (DESIGN.md §7.7): 671B params exceed AdamW-fp32 budgets on a
16 GB/chip v5e pod — the config selects the factored Adafactor state so the
single-pod (256-chip) dry-run fits; multi-pod shards over the pod axis too."""
from repro.configs.base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    d_ff=2048, vocab=129280,
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536, rope_head_dim=64,
                  nope_head_dim=128, v_head_dim=128),
    moe=MoEConfig(num_experts=256, top_k=8, d_expert=2048, num_shared=1,
                  capacity_factor=1.25, first_dense_layers=3, d_ff_dense=18432),
    mtp_depth=1,
    rope_theta=10_000.0,
    optimizer="adafactor", remat="full", fsdp_over_pod=True,
    microbatches=16, grad_accum_dtype="bfloat16",
)
