"""Synthetic graph datasets.

The paper's datasets (PPI / Reddit / Flickr / ogbn-arxiv) are not downloadable
in this offline container, so we generate stochastic-block-model graphs that
match their headline statistics (nodes, avg degree, classes, feature dim) and
carry a planted community↔label correlation so that GNN training is meaningful
and convergence comparisons (LMC vs GAS vs Cluster-GCN) are informative.

Features are drawn from class-conditional Gaussians with controllable SNR, so
full-batch GCN reaches high accuracy and mini-batch methods can be compared on
epochs-to-target exactly like the paper's Table 2 / Figure 2.
"""
from __future__ import annotations

import numpy as np

from repro.graph.structure import Graph

# name -> (nodes, avg_degree, classes, feature_dim)
DATASET_PRESETS: dict[str, tuple[int, float, int, int]] = {
    # CPU-scale stand-ins used by tests/benchmarks (same shape, smaller n)
    "arxiv-cpu": (4096, 13.7, 40, 128),
    "flickr-cpu": (4096, 10.0, 7, 128),
    "reddit-cpu": (4096, 50.0, 41, 128),
    "ppi-cpu": (2048, 28.0, 16, 50),
    # full-scale stand-ins (match paper Table 4 statistics)
    "arxiv-like": (169_343, 13.7, 40, 128),
    "flickr-like": (89_250, 10.0, 7, 500),
    "reddit-like": (232_965, 99.6, 41, 128),
    "ppi-like": (56_944, 27.9, 121, 50),
}


def _sbm_edges(n: int, k: int, comm: np.ndarray, avg_deg: float,
               p_in_frac: float, rng: np.random.Generator
               ) -> tuple[np.ndarray, np.ndarray]:
    """Fast SBM edge sampling: expected-count binomial per block pair."""
    # split expected degree into intra / inter community mass
    deg_in = avg_deg * p_in_frac
    deg_out = avg_deg * (1 - p_in_frac)
    sizes = np.bincount(comm, minlength=k).astype(np.int64)
    starts = np.concatenate([[0], np.cumsum(sizes)])
    # nodes sorted by community for block-local index sampling
    order = np.argsort(comm, kind="stable")

    srcs, dsts = [], []
    for a in range(k):
        na = sizes[a]
        if na < 2:
            continue
        # intra-block: E[edges] = na * deg_in / 2
        m = rng.poisson(na * deg_in / 2.0)
        if m:
            s = order[starts[a] + rng.integers(0, na, m)]
            d = order[starts[a] + rng.integers(0, na, m)]
            srcs.append(s)
            dsts.append(d)
        # inter-block: spread deg_out mass over all other blocks proportionally
        m = rng.poisson(na * deg_out / 2.0)
        if m:
            s = order[starts[a] + rng.integers(0, na, m)]
            d = rng.integers(0, n, m)  # approx: uniform other endpoint
            srcs.append(s)
            dsts.append(d)
    if not srcs:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    return np.concatenate(srcs), np.concatenate(dsts)


def make_sbm_dataset(preset: str = "arxiv-cpu", *, seed: int = 0,
                     p_in_frac: float = 0.85, feature_snr: float = 1.5,
                     label_noise: float = 0.05,
                     splits: tuple[float, float] = (0.6, 0.2)) -> Graph:
    """Build a community-structured graph with learnable labels.

    p_in_frac: fraction of each node's expected degree that stays inside its
        community (higher -> cleaner clusters -> smaller partition edge-cut).
    feature_snr: distance between class feature centroids in noise-σ units.
    """
    if preset not in DATASET_PRESETS:
        raise KeyError(f"unknown preset {preset!r}; options {list(DATASET_PRESETS)}")
    n, avg_deg, k, dx = DATASET_PRESETS[preset]
    rng = np.random.default_rng(seed)

    comm = rng.integers(0, k, n).astype(np.int32)
    src, dst = _sbm_edges(n, k, comm, avg_deg, p_in_frac, rng)

    centroids = rng.normal(0.0, 1.0, (k, dx)).astype(np.float32)
    centroids *= feature_snr / np.sqrt(dx)
    x = centroids[comm] + rng.normal(0, 1.0 / np.sqrt(dx), (n, dx)).astype(np.float32)

    y = comm.copy()
    flip = rng.random(n) < label_noise
    y[flip] = rng.integers(0, k, int(flip.sum()))

    perm = rng.permutation(n)
    n_train = int(splits[0] * n)
    n_val = int(splits[1] * n)
    train_mask = np.zeros(n, bool)
    val_mask = np.zeros(n, bool)
    test_mask = np.zeros(n, bool)
    train_mask[perm[:n_train]] = True
    val_mask[perm[n_train:n_train + n_val]] = True
    test_mask[perm[n_train + n_val:]] = True

    return Graph.from_edges(n, src, dst, x, y.astype(np.int32),
                            train_mask, val_mask, test_mask, name=preset)
