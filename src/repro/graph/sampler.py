"""Cluster mini-batch sampler (Alg. 1 lines 2 & 4 + App. A.3.1 normalization).

Partitions V into B clusters once (preprocessing), then per training step
uniformly samples ``c`` clusters without replacement and emits the padded
extended subgraph. Shapes are fixed per sampler instance so the jitted LMC
step compiles once.
"""
from __future__ import annotations

from typing import Iterator, Optional

import numpy as np

from repro.graph.partition import partition_graph
from repro.graph.structure import Graph, PaddedSubgraph, build_subgraph, padded_sizes_for


class ClusterSampler:
    def __init__(
        self,
        graph: Graph,
        num_parts: int,
        clusters_per_batch: int = 1,
        *,
        seed: int = 0,
        include_halo: bool = True,
        edge_weight_mode: str = "global",
        beta_spec: tuple[str, float] = ("2x-x2", 1.0),
        parts: Optional[np.ndarray] = None,
        stochastic: bool = True,
    ) -> None:
        self.graph = graph
        self.num_parts = int(num_parts)
        self.c = int(clusters_per_batch)
        self.include_halo = include_halo
        self.edge_weight_mode = edge_weight_mode
        self.beta_spec = beta_spec
        self.stochastic = stochastic
        self.rng = np.random.default_rng(seed)
        self.parts = partition_graph(graph, num_parts, seed=seed) if parts is None else parts
        self.degrees = graph.degrees()
        self._nodes_of_part = [np.where(self.parts == p)[0] for p in range(self.num_parts)]
        self.pad_batch, self.pad_halo, self.pad_edges = padded_sizes_for(
            graph, self.parts, self.num_parts, self.c, include_halo)
        self.batches_per_epoch = self.num_parts // self.c

    # -- epoch iteration ----------------------------------------------------
    def epoch(self) -> Iterator[PaddedSubgraph]:
        """Yield B/c batches covering every cluster exactly once (stochastic
        grouping per epoch, matching Cluster-GCN/LMC practice)."""
        order = self.rng.permutation(self.num_parts) if self.stochastic \
            else np.arange(self.num_parts)
        for i in range(self.batches_per_epoch):
            cluster_ids = order[i * self.c:(i + 1) * self.c]
            yield self.build_batch(cluster_ids)

    def sample(self) -> PaddedSubgraph:
        """One uniformly sampled batch of c clusters (Alg. 1 line 4)."""
        cluster_ids = self.rng.choice(self.num_parts, size=self.c, replace=False)
        return self.build_batch(cluster_ids)

    def build_batch(self, cluster_ids: np.ndarray) -> PaddedSubgraph:
        nodes = np.concatenate([self._nodes_of_part[int(p)] for p in cluster_ids])
        return build_subgraph(
            self.graph, nodes,
            pad_batch=self.pad_batch, pad_halo=self.pad_halo,
            pad_edges=self.pad_edges, num_parts=self.num_parts,
            clusters_in_batch=self.c, include_halo=self.include_halo,
            edge_weight_mode=self.edge_weight_mode, beta_spec=self.beta_spec,
            degrees=self.degrees)

    # -- state for checkpoint/restore ----------------------------------------
    def state_dict(self) -> dict:
        return {"bit_generator": self.rng.bit_generator.state}

    def load_state_dict(self, state: dict) -> None:
        self.rng.bit_generator.state = state["bit_generator"]
