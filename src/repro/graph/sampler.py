"""Cluster mini-batch sampler (Alg. 1 lines 2 & 4 + App. A.3.1 normalization).

Partitions V into B clusters once (preprocessing), then per training step
uniformly samples ``c`` clusters without replacement and emits the padded
extended subgraph. Shapes are fixed per sampler instance so the jitted LMC
step compiles once.

Two sampling APIs coexist:

* the *stateful* API (:meth:`ClusterSampler.sample` / ``epoch``) advances the
  sampler's own RNG — the legacy synchronous-trainer path, whose bit-generator
  state rides along in checkpoints;
* the *schedule* API (:meth:`ClusterSampler.clusters_at`) is a pure function
  of ``(seed, index)`` with no mutable state. The async prefetch pipeline
  (``repro.data.prefetch.SubgraphPipeline``) is built on it: batches can be
  constructed by a thread pool in any arrival order and the stream is still
  bit-identical to a synchronous walk of the same indices (DESIGN.md §9).
"""
from __future__ import annotations

from typing import Iterator, Optional

import numpy as np

from repro.graph.partition import partition_graph
from repro.graph.structure import Graph, PaddedSubgraph, build_subgraph, padded_sizes_for

# domain-separation tags for the schedule API's per-index RNG streams, so
# uniform draws, epoch permutations and the stateful RNG can never collide
_SCHED_UNIFORM = 0x5A3D01
_SCHED_EPOCH = 0x5A3D02

SCHEDULE_MODES = ("uniform", "epoch")


class ClusterSampler:
    """Samples c-cluster mini-batches from a fixed partition of the graph.

    Thread-safety: :meth:`build_batch` and :meth:`clusters_at` are read-only
    with respect to sampler state and safe to call concurrently from worker
    threads. :meth:`sample` / :meth:`epoch` mutate ``self.rng`` and must stay
    on a single thread (the synchronous trainer path).
    """

    def __init__(
        self,
        graph: Graph,
        num_parts: int,
        clusters_per_batch: int = 1,
        *,
        seed: int = 0,
        include_halo: bool = True,
        edge_weight_mode: str = "global",
        beta_spec: tuple[str, float] = ("2x-x2", 1.0),
        parts: Optional[np.ndarray] = None,
        stochastic: bool = True,
    ) -> None:
        """Partition ``graph`` (unless ``parts`` is given) and fix batch shapes.

        Args:
            graph: host-side CSR graph to sample from.
            num_parts: number of clusters B the node set is partitioned into.
            clusters_per_batch: clusters c per mini-batch (Alg. 1 line 4).
            seed: seeds both the stateful RNG and the pure schedule API.
            include_halo: keep 1-hop out-of-batch neighbors (LMC/GAS view);
                ``False`` gives the Cluster-GCN batch-internal view.
            edge_weight_mode: ``"global"`` keeps whole-graph GCN normalization,
                ``"local"`` renormalizes by subgraph degrees (Cluster-GCN).
            beta_spec: ``(score, alpha)`` for the β convex-combination
                coefficients (paper App. A.4).
            parts: externally computed partition vector; skips partitioning.
            stochastic: shuffle cluster grouping per :meth:`epoch` call.
        """
        self.graph = graph
        self.num_parts = int(num_parts)
        self.c = int(clusters_per_batch)
        self.include_halo = include_halo
        self.edge_weight_mode = edge_weight_mode
        self.beta_spec = beta_spec
        self.stochastic = stochastic
        self.seed = int(seed)
        self.rng = np.random.default_rng(seed)
        self.parts = partition_graph(graph, num_parts, seed=seed) if parts is None else parts
        self.degrees = graph.degrees()
        self._nodes_of_part = [np.where(self.parts == p)[0] for p in range(self.num_parts)]
        self.pad_batch, self.pad_halo, self.pad_edges = padded_sizes_for(
            graph, self.parts, self.num_parts, self.c, include_halo)
        self.batches_per_epoch = self.num_parts // self.c

    # -- epoch iteration ----------------------------------------------------
    def epoch(self) -> Iterator[PaddedSubgraph]:
        """Yield B/c batches covering every cluster exactly once (stochastic
        grouping per epoch, matching Cluster-GCN/LMC practice).

        Stateful: advances ``self.rng`` when ``stochastic`` is set.
        """
        order = self.rng.permutation(self.num_parts) if self.stochastic \
            else np.arange(self.num_parts)
        for i in range(self.batches_per_epoch):
            cluster_ids = order[i * self.c:(i + 1) * self.c]
            yield self.build_batch(cluster_ids)

    def sample(self) -> PaddedSubgraph:
        """One uniformly sampled batch of c clusters (Alg. 1 line 4).

        Stateful: advances ``self.rng``; see :meth:`clusters_at` for the pure
        schedule-indexed equivalent used by the prefetch pipeline.
        """
        cluster_ids = self.rng.choice(self.num_parts, size=self.c, replace=False)
        return self.build_batch(cluster_ids)

    # -- pure schedule API (prefetch pipeline) -------------------------------
    def clusters_at(self, index: int, *, mode: str = "uniform") -> np.ndarray:
        """Cluster ids for schedule slot ``index`` — pure in ``(seed, index)``.

        ``mode="uniform"`` draws c clusters without replacement, independently
        per slot (the iid sampling of Alg. 1 line 4). ``mode="epoch"`` walks
        shuffled epochs: slot ``index`` maps to epoch ``index // (B/c)`` and
        position ``index % (B/c)`` inside that epoch's permutation, so every
        ``B/c`` consecutive slots cover each cluster exactly once.

        Because the draw depends only on the sampler seed and the slot index
        (not on any mutable RNG state), prefetch workers may build slots in
        any order and a resumed run replays the identical stream — the
        determinism contract of DESIGN.md §9.
        """
        index = int(index)
        if index < 0:
            raise ValueError(f"schedule index must be >= 0, got {index}")
        if mode == "uniform":
            rng = np.random.default_rng([self.seed, _SCHED_UNIFORM, index])
            return rng.choice(self.num_parts, size=self.c, replace=False)
        if mode == "epoch":
            e, s = divmod(index, self.batches_per_epoch)
            rng = np.random.default_rng([self.seed, _SCHED_EPOCH, e])
            order = rng.permutation(self.num_parts)
            return order[s * self.c:(s + 1) * self.c]
        raise ValueError(f"unknown schedule mode {mode!r}; "
                         f"expected one of {SCHEDULE_MODES}")

    def build_batch(self, cluster_ids: np.ndarray) -> PaddedSubgraph:
        """Materialize the padded extended subgraph for given cluster ids.

        Pure (no RNG) and thread-safe: prefetch workers call this
        concurrently for different schedule slots.
        """
        nodes = np.concatenate([self._nodes_of_part[int(p)] for p in cluster_ids])
        return build_subgraph(
            self.graph, nodes,
            pad_batch=self.pad_batch, pad_halo=self.pad_halo,
            pad_edges=self.pad_edges, num_parts=self.num_parts,
            clusters_in_batch=self.c, include_halo=self.include_halo,
            edge_weight_mode=self.edge_weight_mode, beta_spec=self.beta_spec,
            degrees=self.degrees)

    # -- state for checkpoint/restore ----------------------------------------
    def state_dict(self) -> dict:
        """Checkpointable state: the stateful RNG's bit-generator state."""
        return {"bit_generator": self.rng.bit_generator.state}

    def load_state_dict(self, state: dict) -> None:
        """Restore the stateful RNG (deterministic resume of :meth:`sample`)."""
        self.rng.bit_generator.state = state["bit_generator"]
