"""Graph data structures.

Host-side graphs are CSR over numpy; the device-facing mini-batch structure
(:class:`PaddedSubgraph`) is a statically-shaped padded COO over the *extended*
node set ``V_B ∪ (N(V_B) \\ V_B)`` — exactly the working set of LMC's Eq. (8)-(13).

Conventions
-----------
* Local row layout of a subgraph: rows ``[0, n_batch)`` are in-batch nodes,
  rows ``[n_batch, n_batch + n_halo)`` are 1-hop halo nodes.
* Edges are directed ``src -> dst`` message edges; the graph is undirected so
  both directions are materialized. Edges whose *destination* is a halo node and
  whose *source* is outside the extended set do not exist in the subgraph — this
  is what makes halo-row aggregations "incomplete up-to-date" (Eq. 10/13).
* Padding: padded edges have weight 0 and point at row 0; padded node rows have
  mask 0 and global id clipped to a valid index (store scatter/gather uses the
  mask to suppress them).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


def _round_up(x: int, m: int) -> int:
    return ((int(x) + m - 1) // m) * m


# Upper bound on the message-invariance scale α = W_tot/W_in (backend="ti").
# α is the amplification applied to a halo node's in-subgraph messages; a node
# that shares only a sliver of its incident weight with the subgraph would
# otherwise amplify that sliver (and its noise) unboundedly. METIS-style
# partitions keep most weight internal, so the clip is rarely active.
TI_SCALE_CLIP = 32.0


@dataclasses.dataclass
class Graph:
    """Undirected graph in CSR form with features/labels/splits (host side)."""

    indptr: np.ndarray       # (n+1,) int64
    indices: np.ndarray      # (nnz,) int32, symmetric
    x: np.ndarray            # (n, dx) float32 node features
    y: np.ndarray            # (n,) int32 labels
    train_mask: np.ndarray   # (n,) bool
    val_mask: np.ndarray     # (n,) bool
    test_mask: np.ndarray    # (n,) bool
    name: str = "graph"

    @property
    def num_nodes(self) -> int:
        """Node count n."""
        return int(self.indptr.shape[0] - 1)

    @property
    def num_edges(self) -> int:
        """Directed message-edge count (2x undirected edges)."""
        return int(self.indices.shape[0])

    @property
    def num_classes(self) -> int:
        """Label count (max label + 1)."""
        return int(self.y.max()) + 1

    @property
    def feature_dim(self) -> int:
        """Node feature dimension dx."""
        return int(self.x.shape[1])

    def degrees(self) -> np.ndarray:
        """Per-node (directed) degree, shape (n,) int64."""
        return np.diff(self.indptr).astype(np.int64)

    def neighbors(self, v: int) -> np.ndarray:
        """Neighbor ids of node ``v`` (a CSR slice view)."""
        return self.indices[self.indptr[v]:self.indptr[v + 1]]

    @staticmethod
    def from_edges(n: int, src: np.ndarray, dst: np.ndarray, x: np.ndarray,
                   y: np.ndarray, train_mask: np.ndarray, val_mask: np.ndarray,
                   test_mask: np.ndarray, name: str = "graph") -> "Graph":
        """Build a symmetric, dedup'd, self-loop-free CSR graph from edge lists."""
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        keep = src != dst
        src, dst = src[keep], dst[keep]
        # symmetrize + dedup via sorted unique of encoded pairs
        a = np.concatenate([src, dst])
        b = np.concatenate([dst, src])
        code = a * n + b
        code = np.unique(code)
        a, b = code // n, code % n
        order = np.argsort(a, kind="stable")
        a, b = a[order], b[order]
        indptr = np.zeros(n + 1, dtype=np.int64)
        np.add.at(indptr, a + 1, 1)
        indptr = np.cumsum(indptr)
        return Graph(indptr=indptr, indices=b.astype(np.int32), x=x, y=y,
                     train_mask=train_mask, val_mask=val_mask,
                     test_mask=test_mask, name=name)

    def gcn_edge_weights(self, src: np.ndarray, dst: np.ndarray,
                         degrees: Optional[np.ndarray] = None) -> np.ndarray:
        """Symmetric GCN normalization 1/sqrt((d_i+1)(d_j+1)) w/ self loops."""
        if degrees is None:
            degrees = self.degrees()
        d = degrees.astype(np.float64) + 1.0
        return (1.0 / np.sqrt(d[src] * d[dst])).astype(np.float32)


@dataclasses.dataclass
class PaddedSubgraph:
    """Statically-shaped device mini-batch for LMC / GAS / Cluster training.

    All arrays are numpy on build; the trainer moves them to device. Shapes are
    padded to sampler-level maxima so one jit compilation covers an epoch.
    """

    batch_gids: np.ndarray   # (NB,) int32 global ids of in-batch nodes (clipped pad)
    halo_gids: np.ndarray    # (NH,) int32 global ids of halo nodes (clipped pad)
    batch_mask: np.ndarray   # (NB,) float32 1/0 validity
    halo_mask: np.ndarray    # (NH,) float32
    edge_src: np.ndarray     # (E,) int32 local src rows (into [0, NB+NH))
    edge_dst: np.ndarray     # (E,) int32 local dst rows
    edge_w: np.ndarray       # (E,) float32, 0 for padding
    labels: np.ndarray       # (NB+NH,) int32 (0 where unlabeled/pad)
    labeled_mask: np.ndarray  # (NB+NH,) float32: train-labeled & valid
    beta: np.ndarray         # (NH,) float32 convex combination coefficients
    loss_scale: np.ndarray   # () float32: b/(c*|V_L|)  (App. A.3.1, Eq. 14)
    grad_scale: np.ndarray   # () float32: b/c          (App. A.3.1, Eq. 15)
    # (NH,) float32 message-invariance scales α_i = W_tot(i)/W_in(i): ratio of
    # a halo node's full-graph incident GCN edge weight to its in-subgraph
    # incident weight; 0 on padding rows. backend="ti" (DESIGN.md §11) uses
    # α ⊙ fresh as the compensation estimate instead of a store gather.
    ti_scale: np.ndarray = None
    # metadata (host only, not traced)
    n_batch_real: int = 0
    n_halo_real: int = 0
    n_edges_real: int = 0

    @property
    def n_batch(self) -> int:
        """Padded in-batch row count NB."""
        return int(self.batch_gids.shape[0])

    @property
    def n_halo(self) -> int:
        """Padded halo row count NH."""
        return int(self.halo_gids.shape[0])

    @property
    def n_ext(self) -> int:
        """Extended-set row count NB + NH (the local id space)."""
        return self.n_batch + self.n_halo


def beta_score(local_deg: np.ndarray, global_deg: np.ndarray,
               score: str = "2x-x2", alpha: float = 1.0) -> np.ndarray:
    """β_i = score(deg_local/deg_global) * α  (paper App. A.4)."""
    x = local_deg.astype(np.float64) / np.maximum(global_deg, 1)
    if score == "x2":
        s = x * x
    elif score == "2x-x2":
        s = 2 * x - x * x
    elif score == "x":
        s = x
    elif score == "1":
        s = np.ones_like(x)
    elif score == "sin":
        s = np.sin(x)
    else:
        raise ValueError(f"unknown beta score {score!r}")
    return np.clip(s * alpha, 0.0, 1.0).astype(np.float32)


def build_subgraph(
    graph: Graph,
    batch_nodes: np.ndarray,
    *,
    pad_batch: int,
    pad_halo: int,
    pad_edges: int,
    num_parts: int,
    clusters_in_batch: int,
    include_halo: bool = True,
    edge_weight_mode: str = "global",
    beta_spec: tuple[str, float] = ("2x-x2", 1.0),
    degrees: Optional[np.ndarray] = None,
) -> PaddedSubgraph:
    """Construct the padded extended subgraph for a sampled mini-batch.

    ``include_halo=False`` gives the Cluster-GCN view (edges internal to the
    batch only); ``edge_weight_mode='local'`` renormalizes by subgraph degrees
    (Cluster-GCN), ``'global'`` keeps whole-graph GCN normalization (GAS/LMC).
    """
    n = graph.num_nodes
    if degrees is None:
        degrees = graph.degrees()
    batch_nodes = np.asarray(batch_nodes, dtype=np.int64)
    nb = batch_nodes.shape[0]
    if nb > pad_batch:
        raise ValueError(f"batch {nb} exceeds pad_batch {pad_batch}")

    in_batch = np.zeros(n, dtype=bool)
    in_batch[batch_nodes] = True

    # gather all out-edges of batch nodes
    counts = (graph.indptr[batch_nodes + 1] - graph.indptr[batch_nodes]).astype(np.int64)
    nbr_of_batch = np.concatenate(
        [graph.indices[graph.indptr[v]:graph.indptr[v + 1]] for v in batch_nodes]
    ) if nb else np.zeros(0, np.int32)
    dst_rep = np.repeat(batch_nodes, counts)  # edges src=neighbor -> dst=batch node

    if include_halo:
        halo_nodes = np.unique(nbr_of_batch[~in_batch[nbr_of_batch]])
    else:
        halo_nodes = np.zeros(0, dtype=np.int64)
    nh = halo_nodes.shape[0]
    if nh > pad_halo:
        raise ValueError(f"halo {nh} exceeds pad_halo {pad_halo}")

    # local ids: batch rows [0, pad_batch), halo rows [pad_batch, ...)
    local_of = np.full(n, -1, dtype=np.int64)
    local_of[batch_nodes] = np.arange(nb)
    local_of[halo_nodes] = pad_batch + np.arange(nh)

    # Edges into batch rows: every neighbor of a batch node is in the extended set.
    e1_src_g = nbr_of_batch.astype(np.int64)
    e1_dst_g = dst_rep
    if not include_halo:
        keep = in_batch[e1_src_g]
        e1_src_g, e1_dst_g = e1_src_g[keep], e1_dst_g[keep]

    # Edges into halo rows: only sources inside the extended set survive (Eq. 10).
    if nh:
        hcounts = (graph.indptr[halo_nodes + 1] - graph.indptr[halo_nodes]).astype(np.int64)
        nbr_of_halo = np.concatenate(
            [graph.indices[graph.indptr[v]:graph.indptr[v + 1]] for v in halo_nodes])
        hdst = np.repeat(halo_nodes, hcounts)
        keep = local_of[nbr_of_halo] >= 0
        e2_src_g = nbr_of_halo[keep].astype(np.int64)
        e2_dst_g = hdst[keep]
        halo_local_deg = np.bincount(
            np.searchsorted(halo_nodes, e2_dst_g), minlength=nh).astype(np.int64)
        # message-invariance scales (backend="ti", DESIGN.md §11): per halo
        # node, the ratio of its *full-graph* incident GCN edge weight to its
        # *in-subgraph* incident weight. Always the global normalization —
        # W_tot has no meaning under subgraph-local renormalization. W_in > 0
        # for every real halo node (the batch neighbor that pulled it in is
        # in the subgraph, and the graph is symmetric), and W_in ⊆ W_tot so
        # α >= 1; the clip only bounds pathological fringe nodes whose
        # in-subgraph weight is a sliver of their total.
        w_tot = np.bincount(np.searchsorted(halo_nodes, hdst),
                            weights=graph.gcn_edge_weights(
                                nbr_of_halo.astype(np.int64), hdst, degrees),
                            minlength=nh)
        w_in = np.bincount(np.searchsorted(halo_nodes, e2_dst_g),
                           weights=graph.gcn_edge_weights(
                               e2_src_g, e2_dst_g, degrees),
                           minlength=nh)
        halo_ti = np.clip(w_tot / np.maximum(w_in, 1e-12),
                          1.0, TI_SCALE_CLIP).astype(np.float32)
    else:
        e2_src_g = e2_dst_g = np.zeros(0, dtype=np.int64)
        halo_local_deg = np.zeros(0, dtype=np.int64)
        halo_ti = np.zeros(0, dtype=np.float32)

    src_g = np.concatenate([e1_src_g, e2_src_g])
    dst_g = np.concatenate([e1_dst_g, e2_dst_g])
    ne = src_g.shape[0]
    if ne > pad_edges:
        raise ValueError(f"edges {ne} exceed pad_edges {pad_edges}")

    if edge_weight_mode == "global":
        ew = graph.gcn_edge_weights(src_g, dst_g, degrees)
    elif edge_weight_mode == "local":
        # degrees within the sub-view (Cluster-GCN renormalization)
        ld = np.zeros(n, dtype=np.int64)
        np.add.at(ld, dst_g, 1)
        d = ld.astype(np.float64) + 1.0
        ew = (1.0 / np.sqrt(d[src_g] * d[dst_g])).astype(np.float32)
    else:
        raise ValueError(edge_weight_mode)

    # padded arrays
    bg = np.zeros(pad_batch, np.int32)
    bg[:nb] = batch_nodes
    hg = np.zeros(pad_halo, np.int32)
    hg[:nh] = halo_nodes
    bm = np.zeros(pad_batch, np.float32)
    bm[:nb] = 1
    hm = np.zeros(pad_halo, np.float32)
    hm[:nh] = 1
    es = np.zeros(pad_edges, np.int32)
    ed = np.zeros(pad_edges, np.int32)
    ewp = np.zeros(pad_edges, np.float32)
    es[:ne] = local_of[src_g]
    ed[:ne] = local_of[dst_g]
    ewp[:ne] = ew

    n_ext = pad_batch + pad_halo
    labels = np.zeros(n_ext, np.int32)
    labeled = np.zeros(n_ext, np.float32)
    labels[:nb] = graph.y[batch_nodes]
    labeled[:nb] = graph.train_mask[batch_nodes].astype(np.float32)
    if nh:
        labels[pad_batch:pad_batch + nh] = graph.y[halo_nodes]
        labeled[pad_batch:pad_batch + nh] = graph.train_mask[halo_nodes].astype(np.float32)

    score, alpha = beta_spec
    beta = np.zeros(pad_halo, np.float32)
    ti_scale = np.zeros(pad_halo, np.float32)
    if nh:
        beta[:nh] = beta_score(halo_local_deg, degrees[halo_nodes], score, alpha)
        ti_scale[:nh] = halo_ti

    n_labeled_total = max(int(graph.train_mask.sum()), 1)
    b_over_c = float(num_parts) / float(max(clusters_in_batch, 1))
    loss_scale = np.float32(b_over_c / n_labeled_total)
    grad_scale = np.float32(b_over_c)

    return PaddedSubgraph(
        batch_gids=bg, halo_gids=hg, batch_mask=bm, halo_mask=hm,
        edge_src=es, edge_dst=ed, edge_w=ewp, labels=labels,
        labeled_mask=labeled, beta=beta, loss_scale=loss_scale,
        grad_scale=grad_scale, ti_scale=ti_scale,
        n_batch_real=nb, n_halo_real=nh, n_edges_real=ne)


def padded_sizes_for(graph: Graph, parts: np.ndarray, num_parts: int, c: int,
                     include_halo: bool = True) -> tuple[int, int, int]:
    """Worst-case (pad_batch, pad_halo, pad_edges) over any c-cluster batch.

    Conservative: sums the c largest per-cluster stats, rounded up to friendly
    multiples so one jit shape covers every epoch. Per-cluster halo sizes and
    halo volumes are computed exactly (cheap: one CSR sweep per cluster).
    """
    degrees = graph.degrees()
    src = np.repeat(np.arange(graph.num_nodes), np.diff(graph.indptr))
    dst = graph.indices
    sizes = np.bincount(parts, minlength=num_parts).astype(np.int64)
    vol = np.zeros(num_parts, dtype=np.int64)
    np.add.at(vol, parts, degrees)

    # per-cluster halo node count and halo volume (degrees of halo nodes)
    halo_sizes = np.zeros(num_parts, dtype=np.int64)
    halo_vols = np.zeros(num_parts, dtype=np.int64)
    if include_halo:
        cross = parts[src] != parts[dst]
        for p in range(num_parts):
            # halo of cluster p = unique dst of cross edges leaving p
            h = np.unique(dst[cross & (parts[src] == p)])
            halo_sizes[p] = h.size
            halo_vols[p] = degrees[h].sum()

    top_sizes = np.sort(sizes)[::-1][:c].sum()
    top_vol = np.sort(vol)[::-1][:c].sum()
    top_halo = min(np.sort(halo_sizes)[::-1][:c].sum(), graph.num_nodes)
    top_halo_vol = np.sort(halo_vols)[::-1][:c].sum()

    pad_batch = _round_up(top_sizes, 64)
    pad_halo = _round_up(max(top_halo, 1), 64) if include_halo else 64
    # edges into batch rows ≤ batch volume; edges into halo rows ≤ halo volume
    pad_edges = _round_up(top_vol + top_halo_vol + 64, 256)
    return int(pad_batch), int(pad_halo), int(pad_edges)
