"""Graph partitioning (METIS stand-in).

METIS is unavailable in this offline container, so we implement a deterministic
multi-seed BFS + greedy Linear Deterministic Greedy (LDG) streaming partitioner
with a boundary-refinement pass. Quality (edge-cut) is reported by
:func:`edge_cut_fraction` and recorded in EXPERIMENTS.md; for the SBM-style
benchmark graphs it recovers community structure almost exactly, which is the
property Cluster-GCN/GAS/LMC rely on.

The interface also accepts externally computed partition vectors, so a real
deployment can swap METIS/KaHIP in without touching the trainer.
"""
from __future__ import annotations

from collections import deque

import numpy as np

from repro.graph.structure import Graph


def _bfs_order(graph: Graph, rng: np.random.Generator) -> np.ndarray:
    """Node visitation order by BFS from random seeds (one per component)."""
    n = graph.num_nodes
    seen = np.zeros(n, dtype=bool)
    order = np.empty(n, dtype=np.int64)
    pos = 0
    perm = rng.permutation(n)
    q: deque[int] = deque()
    for s in perm:
        if seen[s]:
            continue
        seen[s] = True
        q.append(int(s))
        while q:
            v = q.popleft()
            order[pos] = v
            pos += 1
            for u in graph.neighbors(v):
                if not seen[u]:
                    seen[u] = True
                    q.append(int(u))
    assert pos == n
    return order


def partition_graph(graph: Graph, num_parts: int, *, seed: int = 0,
                    slack: float = 1.05, refine_iters: int = 2) -> np.ndarray:
    """Partition nodes into ``num_parts`` balanced parts, minimizing edge cut.

    LDG objective: assign v to argmax_p |N(v) ∩ P_p| * (1 - |P_p|/cap).
    """
    n = graph.num_nodes
    if num_parts <= 1:
        return np.zeros(n, dtype=np.int32)
    rng = np.random.default_rng(seed)
    cap = max(1.0, slack * n / num_parts)
    parts = np.full(n, -1, dtype=np.int32)
    fill = np.zeros(num_parts, dtype=np.int64)

    order = _bfs_order(graph, rng)
    nbr_count = np.zeros(num_parts, dtype=np.float64)
    for v in order:
        nbr_count[:] = 0.0
        for u in graph.neighbors(v):
            p = parts[u]
            if p >= 0:
                nbr_count[p] += 1.0
        score = nbr_count * (1.0 - fill / cap)
        # fall back to least-filled part when no placed neighbors
        if nbr_count.max() <= 0.0 or score.max() <= 0.0:
            p = int(np.argmin(fill))
        else:
            p = int(np.argmax(score))
        if fill[p] >= cap:
            avail = np.where(fill < cap)[0]
            p = int(avail[np.argmax(score[avail])]) if avail.size else int(np.argmin(fill))
        parts[v] = p
        fill[p] += 1

    for _ in range(refine_iters):
        moved = _refine_boundary(graph, parts, fill, cap)
        if moved == 0:
            break
    return parts


def _refine_boundary(graph: Graph, parts: np.ndarray, fill: np.ndarray,
                     cap: float) -> int:
    """Greedy single-pass boundary refinement: move a node to the neighbor-majority
    part when that strictly reduces cut and respects balance."""
    n = graph.num_nodes
    num_parts = fill.shape[0]
    moved = 0
    gain_buf = np.zeros(num_parts, dtype=np.int64)
    for v in range(n):
        nbrs = graph.neighbors(v)
        if nbrs.size == 0:
            continue
        gain_buf[:] = 0
        np.add.at(gain_buf, parts[nbrs], 1)
        cur = parts[v]
        best = int(np.argmax(gain_buf))
        if best != cur and gain_buf[best] > gain_buf[cur] and fill[best] + 1 <= cap:
            parts[v] = best
            fill[cur] -= 1
            fill[best] += 1
            moved += 1
    return moved


def edge_cut_fraction(graph: Graph, parts: np.ndarray) -> float:
    """Fraction of (directed) edges whose endpoints lie in different parts."""
    src = np.repeat(np.arange(graph.num_nodes), np.diff(graph.indptr))
    cut = (parts[src] != parts[graph.indices]).sum()
    return float(cut) / max(graph.num_edges, 1)


def partition_balance(parts: np.ndarray, num_parts: int) -> float:
    """max part size / mean part size (1.0 = perfectly balanced)."""
    sizes = np.bincount(parts, minlength=num_parts)
    return float(sizes.max() / max(sizes.mean(), 1e-9))
