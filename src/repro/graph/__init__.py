"""Graph substrate: structures, partitioning, sampling, synthetic datasets."""
from repro.graph.structure import Graph, PaddedSubgraph, build_subgraph
from repro.graph.partition import partition_graph, edge_cut_fraction
from repro.graph.sampler import ClusterSampler
from repro.graph.synthetic import make_sbm_dataset, DATASET_PRESETS

__all__ = [
    "Graph", "PaddedSubgraph", "build_subgraph",
    "partition_graph", "edge_cut_fraction",
    "ClusterSampler", "make_sbm_dataset", "DATASET_PRESETS",
]
