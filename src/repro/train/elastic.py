"""Elastic rescaling: continue training after the device pool changes.

Params and optimizer state reshard exactly (checkpoint.reshard). The LMC
historical stores are *soft state*: Thm 2 bounds the staleness contribution by
C·ρ^{(k-1)/2}, so after a rescale they can be (a) resharded like params, or
(b) cold-reinitialized, paying only a transient bias spike that decays
geometrically — the cheap path when the node-partition itself changed
(cluster count is retuned to the new device count).
"""
from __future__ import annotations

from repro.core import HistoricalState, init_history
from repro.graph import ClusterSampler
from repro.graph.partition import partition_graph


def rescale_lmc_state(graph, store: HistoricalState, *,
                      old_num_parts: int, new_num_parts: int, seed: int = 0,
                      reuse_store: bool = True, guard=None
                      ) -> tuple[ClusterSampler, HistoricalState]:
    """Re-partition for a new device count and carry (or reset) the stores.

    The historical values are per-*node*, so they survive a re-partition
    unchanged when `reuse_store` (partition only changes which rows are
    updated together); resetting them is also sound (Thm 2).

    ``guard`` (a ``train.health.HealthGuard``, optional) keeps the Thm-2
    staleness accounting honest across the rescale: a reused store carries
    its counters (row ages are unchanged by re-partitioning), while a cold
    reinit zeroes them (every row is byte-fresh — the transient bias of the
    reset is what decays as ρ^k, not row staleness).
    """
    parts = partition_graph(graph, new_num_parts, seed=seed)
    sampler = ClusterSampler(graph, new_num_parts, parts=parts, seed=seed)
    if reuse_store:
        new_store = store
    else:
        L, _, d = store.h.shape
        new_store = init_history(L, graph.num_nodes, d)
        if guard is not None:
            guard.reset_staleness()
    return sampler, new_store
