from repro.train.elastic import rescale_lmc_state
from repro.train.health import (FailureInjector, FaultPlan, HealthConfig,
                                HealthGuard, PipelineFault,
                                SimulatedPreemption, StalenessBudgetError,
                                TrainingDivergedError)
from repro.train.loop import GNNTrainer

__all__ = ["GNNTrainer", "FailureInjector", "FaultPlan", "HealthConfig",
           "HealthGuard", "PipelineFault", "SimulatedPreemption",
           "StalenessBudgetError", "TrainingDivergedError",
           "rescale_lmc_state"]
