from repro.train.loop import GNNTrainer, FailureInjector
from repro.train.elastic import rescale_lmc_state

__all__ = ["GNNTrainer", "FailureInjector", "rescale_lmc_state"]
