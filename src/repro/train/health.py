"""Numerical-health supervision + layered fault injection (DESIGN.md §10).

LMC's convergence guarantee (Thm 2) only holds while (a) the iterates stay
finite and (b) the historical-store staleness stays within the ρ-budget the
theorem's geometric bias term assumes. Two pieces live here:

* :class:`HealthGuard` — per-step numerical-health checks (NaN/Inf in
  loss / grad-norm / store, loss-spike anomalies against a rolling-median
  baseline) plus per-layer store-staleness counters, so the ρ-budget is an
  enforced invariant rather than a docstring comment. The guard only
  *detects*; the recovery policy (rollback-to-checkpoint with bounded
  retries and optional lr-backoff, or skip-batch) is executed by
  ``GNNTrainer.run``, which is where the checkpoint and the pipeline live.

* :class:`FaultPlan` — the layered fault-injection framework generalizing
  the old single-class ``FailureInjector``. One plan schedules any mix of
  fault classes, each firing exactly once (so a recovered retry of the same
  step/slot is clean, keeping the post-recovery stream deterministic):

    preemption   — raises :class:`SimulatedPreemption` at step start
                   (crash/SIGTERM; recovery = restore latest checkpoint);
    pipeline     — raises :class:`PipelineFault` inside a pipeline worker
                   building the scheduled slot (recovery = rebuild the
                   pipeline at the current step; the stream is a pure
                   function of the step index so the retry is identical);
    ckpt-write   — raises :class:`CheckpointWriteFault` mid-save, between
                   leaf writes (recovery = none needed: the atomic tmp-dir
                   protocol leaves the previous checkpoint intact);
    nan-batch    — poisons the scheduled step's batch with NaN edge
                   weights, driving loss and gradients NaN (recovery =
                   the HealthGuard policy above).

Both classes are host-side pure-Python; nothing here runs under jit.
Cheap recovery is sound because store staleness bias decays geometrically
(Thm 2; also the follow-up arXiv 2303.11081) — rolling back or even
resetting the store costs only a transient bias spike.
"""
from __future__ import annotations

import math
import threading
from collections import deque
from dataclasses import dataclass
from typing import Optional

import numpy as np

# One shared ρ-budget definition (core/methods.py, next to MBMethod) so the
# training tier's enforcement here and the serving tier's degradation policy
# (serve/policy.py) cannot drift apart. Re-exported for callers that
# configure HealthConfig.rho_budget.
from repro.core.methods import RHO_BUDGET_DEFAULT

__all__ = [
    "RHO_BUDGET_DEFAULT", "SimulatedPreemption", "PipelineFault",
    "CheckpointWriteFault", "TrainingDivergedError", "StalenessBudgetError",
    "ServeWorkerFault", "FaultPlan", "FailureInjector", "HealthConfig",
    "HealthGuard",
]


# ----------------------------------------------------------------- fault types
class SimulatedPreemption(RuntimeError):
    """Injected crash/preemption (the old FailureInjector's fault class)."""


class PipelineFault(RuntimeError):
    """Injected batch-pipeline worker crash (fires while building a slot)."""


class CheckpointWriteFault(OSError):
    """Injected checkpoint-write failure (fires mid-save, between leaves)."""


class TrainingDivergedError(RuntimeError):
    """Recovery budget (``max_retries``) exhausted without a healthy step."""


class StalenessBudgetError(RuntimeError):
    """Strict ρ-budget enforcement: halo staleness exceeded ``rho_budget``."""


class ServeWorkerFault(RuntimeError):
    """Injected serving-worker crash (fires inside a batch execution)."""


# ------------------------------------------------------------------ FaultPlan
class FaultPlan:
    """Deterministic, one-shot schedule of injected faults (tests/drills).

    Each fault is keyed by (kind, index) and fires at most once: after the
    trainer recovers and retries the same step/slot, the retry runs clean,
    which is what makes every fault class resumable to a stream-identical
    run. Thread-safe — ``pipeline`` faults fire on pipeline worker threads
    and ``ckpt-write`` faults may fire on the background checkpoint writer.
    """

    def __init__(self, *, preempt_at: tuple = (), pipeline_at: tuple = (),
                 ckpt_write_at: tuple = (), nan_batch_at: tuple = (),
                 serve_slow_at: tuple = (), serve_poison_at: tuple = (),
                 serve_crash_at: tuple = (), serve_burst_at: tuple = (),
                 serve_slow_s: float = 0.25, serve_burst_n: int = 32):
        """Schedule faults by global step index (``pipeline_at``: by slot;
        ``serve_*_at``: by the server's batch sequence number, except
        ``serve_burst_at`` which is keyed by the driver's request index).

        Args:
            preempt_at: steps at which a SimulatedPreemption is raised.
            pipeline_at: schedule *slots* whose worker build raises
                PipelineFault (slot == step when ``recycle == 1``).
            ckpt_write_at: steps whose checkpoint save fails mid-write.
            nan_batch_at: steps whose batch is poisoned with NaN weights.
            serve_slow_at: serving batches stalled for ``serve_slow_s``
                before execution (hung-batch drill; recovery = per-request
                deadlines turn the stall into typed timeout responses).
            serve_poison_at: serving batches whose historical-store halo
                rows are NaN-poisoned right before the batch reads them
                (recovery = crc/NaN detection degrades to the ti path and
                repairs the rows).
            serve_crash_at: serving batches whose execution raises
                :class:`ServeWorkerFault` (recovery = bounded in-place
                retry, the serving analogue of a worker respawn).
            serve_burst_at: request indices at which the *driver* should
                inject a burst of ``serve_burst_n`` extra requests
                (queue-overflow drill; recovery = typed Overloaded
                load-shedding, never unbounded blocking).
            serve_slow_s: stall duration for ``serve_slow_at`` batches.
            serve_burst_n: burst size for ``serve_burst_at`` indices.
        """
        self._at = {"preempt": set(preempt_at), "pipeline": set(pipeline_at),
                    "ckpt": set(ckpt_write_at), "nan": set(nan_batch_at),
                    "serve-slow": set(serve_slow_at),
                    "serve-poison": set(serve_poison_at),
                    "serve-crash": set(serve_crash_at),
                    "serve-burst": set(serve_burst_at)}
        self.serve_slow_s = float(serve_slow_s)
        self.serve_burst_n = int(serve_burst_n)
        self.fired: set = set()
        self._lock = threading.Lock()

    def _fire(self, kind: str, key: int) -> bool:
        """Check-and-mark: True exactly once per scheduled (kind, key)."""
        with self._lock:
            if key in self._at[kind] and (kind, key) not in self.fired:
                self.fired.add((kind, key))
                return True
        return False

    # ------------------------------------------------------------ injection
    def maybe_fail(self, step: int) -> None:
        """Raise SimulatedPreemption if one is scheduled for ``step``."""
        if self._fire("preempt", step):
            raise SimulatedPreemption(f"simulated preemption at step {step}")

    def pipeline_hook(self, slot: int) -> None:
        """Worker-side build hook: raise PipelineFault at a scheduled slot."""
        if self._fire("pipeline", slot):
            raise PipelineFault(f"injected pipeline-worker crash at slot {slot}")

    def ckpt_hook(self, step: int, phase: str) -> None:
        """CheckpointManager write hook: fail a scheduled step's save.

        ``phase`` is ``"leaf_<i>"`` before each leaf write or ``"manifest"``
        before publication; the injection fires once partway through the
        leaf writes so the tmp dir is non-trivially populated when it dies.
        """
        if phase.startswith("leaf_") and phase != "leaf_0":
            if self._fire("ckpt", step):
                raise CheckpointWriteFault(
                    f"injected checkpoint-write failure at step {step} "
                    f"({phase})")

    def corrupt_batch(self, step: int, batch):
        """Return ``batch`` poisoned with NaN edge weights at a scheduled
        step (loss and gradients go NaN downstream), else unchanged."""
        if self._fire("nan", step):
            return batch._replace(edge_w=batch.edge_w * float("nan"))
        return batch

    # ------------------------------------------------- serving fault classes
    def serve_delay(self, seq: int) -> float:
        """Stall duration (s) for serving batch ``seq`` (0.0 = no fault).

        The server sleeps this long before executing the batch — the
        slow/hung-batch drill. Per-request deadlines must convert the stall
        into typed timeout responses, never a hang.
        """
        return self.serve_slow_s if self._fire("serve-slow", seq) else 0.0

    def serve_poison(self, seq: int) -> bool:
        """Whether serving batch ``seq``'s store halo rows get NaN-poisoned.

        The server owns the store, so it applies the poison itself (the plan
        only schedules it); crc verification or the NaN circuit breaker must
        then degrade the batch to the store-free ti path and repair the rows.
        """
        return self._fire("serve-poison", seq)

    def serve_crash_hook(self, seq: int) -> None:
        """Raise :class:`ServeWorkerFault` inside serving batch ``seq``'s
        execution (worker-crash drill; recovery = bounded in-place retry)."""
        if self._fire("serve-crash", seq):
            raise ServeWorkerFault(
                f"injected serving-worker crash at batch {seq}")

    def serve_burst(self, request_idx: int) -> int:
        """Extra requests the driver should inject at ``request_idx``
        (queue-overflow drill), or 0. The admission queue must shed the
        overflow with typed Overloaded responses."""
        return self.serve_burst_n if self._fire("serve-burst", request_idx) \
            else 0


class FailureInjector(FaultPlan):
    """Back-compat shim: the original preemption-only injector."""

    def __init__(self, fail_at_steps: tuple = ()):
        """Schedule preemptions at the given global step indices."""
        super().__init__(preempt_at=fail_at_steps)


# ---------------------------------------------------------------- HealthGuard
@dataclass
class HealthConfig:
    """Knobs for :class:`HealthGuard` + the trainer's recovery policy.

    Attributes:
        policy: recovery action on a divergent step — ``"rollback"``
            (restore the newest verifiable checkpoint, bounded by the
            trainer's ``max_retries``, optionally backing off the lr) or
            ``"skip-batch"`` (drop the poisoned update and move on).
        spike_factor: a step whose loss exceeds ``spike_factor`` × the
            rolling-median baseline is flagged as a divergence anomaly.
        window: rolling-baseline length (recent accepted-step losses).
        warmup: accepted steps before spike detection arms (the baseline
            median is meaningless while the window is nearly empty).
        lr_backoff: multiply the trainer's lr by this on every rollback
            (1.0 = keep lr; rollback then replays an identical stream).
        grad_norm_limit: optional hard bound on the clipped global grad
            norm (NaN/Inf is always flagged; this catches finite blowups).
        store_check_every: sweep the historical store for NaN/Inf every k
            accepted steps (0 disables; one jnp.isfinite reduction per
            sweep, off the jit hot path).
        rho_budget: max tolerated staleness (in steps) of any historical
            row *read* this step (the batch's halo rows — exactly the rows
            whose staleness drives Thm 2's bias term). ``None`` records
            the counters without enforcing a bound; the standard budget is
            :data:`repro.core.methods.RHO_BUDGET_DEFAULT`, the one shared
            definition the serving tier's degradation policy also reads.
        rho_strict: raise :class:`StalenessBudgetError` on a budget
            violation instead of recording a history event.
    """

    policy: str = "rollback"
    spike_factor: float = 25.0
    window: int = 64
    warmup: int = 16
    lr_backoff: float = 1.0
    grad_norm_limit: Optional[float] = None
    store_check_every: int = 25
    rho_budget: Optional[int] = None
    rho_strict: bool = False

    def validate(self) -> None:
        """Fail fast on out-of-range knobs."""
        if self.policy not in ("rollback", "skip-batch"):
            raise ValueError(f"unknown health policy {self.policy!r}")
        if self.spike_factor <= 1.0:
            raise ValueError("spike_factor must be > 1")
        if not 0.0 < self.lr_backoff <= 1.0:
            raise ValueError("lr_backoff must be in (0, 1]")


class HealthGuard:
    """Per-step numerical-health checks + per-layer store-staleness counters.

    Pure detector: ``check_step`` / ``check_store`` return a reason string
    (or None) and mutate nothing but the guard's own counters; the trainer
    decides what to do. Counters are host-side numpy — ``staleness[l, i]``
    is the number of accepted steps since store row (layer l, node i) was
    last rewritten, so ``staleness.max()`` is the realized ρ of Thm 2's
    bias bound and skip-store straggler steps / recycling show up directly.
    """

    def __init__(self, config: HealthConfig, num_layers: int, num_nodes: int):
        """Allocate the rolling loss baseline and (L, n) staleness counters."""
        config.validate()
        self.config = config
        self.losses: deque = deque(maxlen=config.window)
        self.staleness = np.zeros((num_layers, num_nodes), np.int32)
        self.num_incidents = 0   # divergent steps detected (for reporting)

    # ------------------------------------------------------------- detection
    def check_step(self, loss: float, grad_norm: float) -> Optional[str]:
        """NaN/Inf + loss-spike check for one step; reason or None.

        Call *before* applying the update, with the candidate step's host
        loss/grad-norm floats (the trainer already pays these syncs for its
        history record, so the check adds no extra device round-trip).
        """
        cfg = self.config
        if not math.isfinite(loss):
            self.num_incidents += 1
            return f"non-finite loss ({loss})"
        if not math.isfinite(grad_norm):
            self.num_incidents += 1
            return f"non-finite grad norm ({grad_norm})"
        if cfg.grad_norm_limit is not None and grad_norm > cfg.grad_norm_limit:
            self.num_incidents += 1
            return (f"grad norm {grad_norm:.3g} exceeds limit "
                    f"{cfg.grad_norm_limit:.3g}")
        if len(self.losses) >= self.config.warmup:
            base = float(np.median(self.losses))
            if loss > cfg.spike_factor * max(base, 1e-12):
                self.num_incidents += 1
                return (f"loss spike {loss:.4g} > {cfg.spike_factor:g}x "
                        f"rolling median {base:.4g}")
        return None

    def check_store(self, store) -> Optional[str]:
        """NaN/Inf sweep over the historical store (one device reduction)."""
        import jax.numpy as jnp
        if not bool(jnp.all(jnp.isfinite(store.h))):
            self.num_incidents += 1
            return "non-finite values in historical embedding store (h)"
        if not bool(jnp.all(jnp.isfinite(store.v))):
            self.num_incidents += 1
            return "non-finite values in historical auxiliary store (v)"
        return None

    def store_check_due(self, step: int) -> bool:
        """Whether the periodic store sweep fires on this step index."""
        k = self.config.store_check_every
        return bool(k) and step % k == 0

    # ------------------------------------------------------------- baseline
    def observe(self, loss: float) -> None:
        """Push an *accepted* step's loss into the rolling baseline.

        Rejected (divergent) losses must never enter the window — a NaN or
        spike would poison the median the next checks compare against.
        """
        self.losses.append(float(loss))

    # ------------------------------------------------------------ staleness
    def halo_staleness(self, halo_gids: np.ndarray,
                       halo_mask: np.ndarray) -> int:
        """Max staleness (steps) over the historical rows read this step.

        These are the batch's (masked) halo rows — the rows whose age feeds
        Thm 2's ρ bias term — so this is the quantity ``rho_budget`` bounds.
        """
        gids = np.asarray(halo_gids)[np.asarray(halo_mask) > 0]
        if gids.size == 0:
            return 0
        return int(self.staleness[:, gids].max())

    def tick(self, batch_gids: np.ndarray, batch_mask: np.ndarray,
             store_updated: bool) -> None:
        """Advance the counters for one accepted step.

        Every row ages one step; the batch rows reset to zero iff the step's
        store update was applied (a skip-store straggler step ages them
        instead — exactly the extra staleness the Thm-2 budget must absorb).
        """
        self.staleness += 1
        if store_updated:
            gids = np.asarray(batch_gids)[np.asarray(batch_mask) > 0]
            self.staleness[:, gids] = 0

    def check_rho_budget(self, halo_staleness: int) -> Optional[str]:
        """Enforce ``rho_budget`` against this step's realized halo
        staleness; returns the violation reason (or raises when strict)."""
        budget = self.config.rho_budget
        if budget is None or halo_staleness <= budget:
            return None
        msg = (f"store staleness {halo_staleness} exceeds the rho budget "
               f"{budget} (Thm 2)")
        if self.config.rho_strict:
            raise StalenessBudgetError(msg)
        return msg

    def reset_staleness(self) -> None:
        """Zero the counters (store reinit / elastic rescale / restore)."""
        self.staleness[:] = 0
