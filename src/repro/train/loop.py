"""Fault-tolerant, health-supervised training loop (GNN + LMC).

Production behaviors implemented (tests: test_fault_tolerance.py,
test_supervisor.py):
  * periodic atomic checkpoints of (params, opt state, historical stores,
    sampler RNG state, lr, step counter) — synchronous or, with
    ``async_ckpt=True``, written on a background thread off the hot path;
  * crash/preemption recovery: on failure the loop restores the newest
    *verifiable* checkpoint and continues (a corrupt/truncated latest
    checkpoint falls back to the previous one — checkpoint.CheckpointError);
  * numerical-health supervision (``health=HealthConfig(...)``): every step
    is checked for NaN/Inf loss/grad-norm, loss spikes against a rolling
    baseline, and (periodically) store corruption *before* its update is
    applied; a divergent step triggers the configured policy — rollback to
    the last good checkpoint (bounded by ``max_retries``, optional
    lr-backoff) or skip-batch — and per-layer store-staleness counters
    enforce Thm 2's ρ-budget (DESIGN.md §10);
  * layered fault injection (``train.health.FaultPlan``): preemptions,
    pipeline-worker crashes, mid-save checkpoint failures and NaN-poisoned
    batches all recover to a stream-deterministic resume;
  * straggler mitigation: a per-step deadline (k × running-median step time);
    a straggler step's *store updates* can be dropped without violating LMC's
    convergence assumptions (staleness is bounded by Thm 2's ρ-term — see
    DESIGN.md §4), which is what `straggler_policy="skip-store"` does;
  * deterministic resume: the sampler's bit-generator state rides along.

``backend="ell"`` switches the jit'd step onto the Pallas bucketed-ELL
SpMM/compensate kernels (compiled on TPU, interpreter fallback on CPU);
batches are then built with their adjacency re-bucketed host-side
(`to_device_batch(sg, backend="ell")`). ``backend="ti"`` keeps the ELL
aggregation but compensates halo rows with the store-free message-invariance
estimator (DESIGN.md §11) — pair it with ``method=repro.core.TI`` so the
(unread) store refresh is skipped too.

``prefetch``/``recycle`` route batch construction through the async
``SubgraphPipeline`` (repro.data.prefetch, DESIGN.md §9): sampling + ELL
bucketing move to background threads, host→device transfers double-buffer
behind the step, and each subgraph can be recycled for ρ consecutive steps.
The pipeline stream is a pure function of (sampler seed, step index), so
checkpoint resume stays deterministic — the pipeline is simply rebuilt at
the restored step. The default (``prefetch=None, recycle=1``) keeps the
legacy synchronous, stateful-RNG path byte-for-byte.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointError, CheckpointManager
from repro.core import (HistoricalState, MBMethod, from_graph, accuracy,
                        init_history, make_train_step, to_device_batch)
from repro.data.prefetch import SubgraphPipeline
from repro.graph import ClusterSampler
from repro.models.gnn import GNN
from repro.optim.optimizers import Optimizer
from repro.train.health import (FailureInjector, FaultPlan, HealthConfig,
                                HealthGuard, PipelineFault,
                                SimulatedPreemption, TrainingDivergedError)

# running-median straggler baseline: bounded so the median scan stays O(1)
# in run length (satellite of DESIGN.md §10; was an unbounded list)
_STEP_TIME_WINDOW = 512


class _Divergence(RuntimeError):
    """Internal: a step failed its health check before being applied."""


class GNNTrainer:
    """Orchestrates sampling, the jit'd LMC step, optimizer updates,
    checkpointing, health supervision and fault handling for one run.

    Not thread-safe: one trainer per (single) training thread; background
    work (batch construction, async checkpoint writes) is delegated to
    ``SubgraphPipeline`` workers / the ``CheckpointManager`` writer thread.
    Call :meth:`close` (or drop the trainer) to stop those workers.
    """

    def __init__(self, gnn: GNN, method: MBMethod, graph, sampler: ClusterSampler,
                 optimizer: Optimizer, *, ckpt_dir: Optional[str] = None,
                 ckpt_every: int = 50, seed: int = 0,
                 failure_injector: Optional[FaultPlan] = None,
                 health: Optional[HealthConfig] = None,
                 max_retries: int = 3,
                 async_ckpt: bool = False,
                 straggler_deadline: float = 4.0,
                 straggler_policy: str = "skip-store",
                 backend: str = "segment",
                 stream: Optional[bool] = None,
                 prefetch: Optional[int] = None,
                 recycle: int = 1,
                 pipeline_workers: int = 2,
                 pipeline_mode: str = "uniform"):
        """Build the jit'd step and (lazily) the batch pipeline.

        Args:
            gnn / method / graph / sampler / optimizer: the model, the
                mini-batch method config (LMC/GAS/...), the host graph, its
                cluster sampler and the optimizer.
            ckpt_dir / ckpt_every: enable periodic atomic checkpoints.
            seed: parameter-init PRNG seed.
            failure_injector: a ``train.health.FaultPlan`` scheduling any
                mix of injected faults (preemptions, pipeline-worker
                crashes, mid-save checkpoint failures, NaN batches); the
                legacy ``FailureInjector`` is a preemption-only FaultPlan.
            health: enable the numerical-health guard with this config
                (``HealthConfig()`` for defaults); None disables all
                health checks (the pre-supervisor hot path).
            max_retries: recovery budget — consecutive recovery actions
                (rollbacks / skipped batches / pipeline rebuilds) allowed
                without an intervening healthy step before the run aborts
                with ``TrainingDivergedError``.
            async_ckpt: write checkpoints on a background thread (the hot
                path only pays the device→host snapshot; files are
                byte-identical to synchronous saves).
            straggler_deadline / straggler_policy: per-step deadline as a
                multiple of the running-median step time; ``"skip-store"``
                drops a straggler step's store update (Thm 2-safe).
            backend: aggregation/compensation hot path, ``"segment"`` |
                ``"ell"`` | ``"ti"`` (store-free message invariance).
            stream: HBM→VMEM DMA gather knob for the ell kernels
                (None = autodetect).
            prefetch: queue depth of the async batch pipeline. ``None``
                (default) keeps the legacy synchronous stateful-RNG path;
                ``0`` uses the pipeline's schedule-indexed stream but builds
                synchronously (debugging / equality tests); ``>= 1`` builds
                ahead on background threads with double-buffered transfers.
            recycle: reuse each sampled subgraph for this many consecutive
                steps (ρ; implies the pipeline path when > 1).
            pipeline_workers: builder threads when prefetching.
            pipeline_mode: schedule of the pipeline path — ``"uniform"``
                (iid cluster draws, Alg. 1 line 4) or ``"epoch"`` (shuffled
                epochs: every cluster exactly once per B/c distinct slots).
        """
        self.gnn = gnn
        self.method = method
        self.graph = graph
        self.sampler = sampler
        self.opt = optimizer
        self.data = from_graph(graph)
        self.failure_injector = failure_injector
        self.straggler_deadline = straggler_deadline
        self.straggler_policy = straggler_policy
        self.backend = backend  # hot path: "segment" | "ell" | "ti"
        self.stream = stream    # HBM→VMEM DMA gather knob (None: autodetect)
        if recycle < 1:
            raise ValueError(f"recycle must be >= 1, got {recycle}")
        if max_retries < 1:
            raise ValueError(f"max_retries must be >= 1, got {max_retries}")
        self.prefetch = prefetch
        self.recycle = int(recycle)
        self.pipeline_workers = int(pipeline_workers)
        self.pipeline_mode = pipeline_mode
        # pipeline path whenever asked for (prefetch set) or needed (ρ > 1);
        # built lazily so it always starts at the current step (resume-safe)
        self._use_pipeline = prefetch is not None or self.recycle > 1
        self._pipeline: Optional[SubgraphPipeline] = None

        self.params = gnn.init_params(jax.random.key(seed))
        self.opt_state = optimizer.init(self.params, _as_pspec_tree(self.params))
        self.store = init_history(gnn.num_layers, graph.num_nodes,
                                  gnn.hidden_dim)
        self.step_num = 0
        self.lr = float(optimizer.lr)   # mutable: rollback lr-backoff
        # no buffer donation: the straggler skip-store policy, health
        # rollback and elastic rescale all need the pre-step state alive
        self._step = jax.jit(make_train_step(gnn, method, graph.num_nodes,
                                             backend=backend, stream=stream))
        # lr rides as a traced array argument so backoff never retraces
        self._update = jax.jit(
            lambda g, s, p, lr: optimizer.update(g, s, p, lr))
        fault_hook = (failure_injector.ckpt_hook
                      if isinstance(failure_injector, FaultPlan) else None)
        self.ckpt = (CheckpointManager(ckpt_dir, fault_hook=fault_hook)
                     if ckpt_dir else None)
        self.ckpt_every = ckpt_every
        self.async_ckpt = bool(async_ckpt)
        self.health = health
        self.guard = (HealthGuard(health, gnn.num_layers, graph.num_nodes)
                      if health is not None else None)
        self.max_retries = int(max_retries)
        self._retries_left = self.max_retries
        self._step_times: deque[float] = deque(maxlen=_STEP_TIME_WINDOW)
        self.history: list[dict] = []

    # ----------------------------------------------------------------- state
    def _state_tree(self):
        return {"params": self.params, "opt": self.opt_state,
                "store": tuple(self.store)}

    def save(self) -> None:
        """Write an atomic checkpoint (params/opt/stores/sampler RNG/lr/step).

        With ``async_ckpt`` the write happens on the manager's background
        thread; this call only pays the device→host snapshot. A failed
        write (injected or real) surfaces as OSError here — the caller's
        recovery is simply to keep training, since the atomic publication
        protocol leaves the previous checkpoint intact.
        """
        if self.ckpt is None:
            return
        extras = {"step": self.step_num, "lr": self.lr,
                  "sampler": _jsonable(self.sampler.state_dict())}
        self.ckpt.save(self.step_num, self._state_tree(), extras,
                       background=self.async_ckpt)

    def restore(self) -> bool:
        """Restore the newest verifiable checkpoint; False when none exists.

        Corrupt/truncated checkpoints are skipped (checkpoint.manager walks
        newest-first with per-leaf checksum verification). Also discards any
        in-flight batch pipeline: the stream is a pure function of the step
        index, so rebuilding it at the restored step replays exactly the
        batches the uninterrupted run would have seen.
        """
        if self.ckpt is None or self.ckpt.latest_step() is None:
            return False
        try:
            tree, extras, step = self.ckpt.restore(self._state_tree())
        except CheckpointError as e:
            # no verifiable checkpoint at all: report and start clean
            self.history.append({"step": self.step_num,
                                 "event": "restore-failed", "error": str(e)})
            return False
        self.params = tree["params"]
        self.opt_state = tree["opt"]
        self.store = HistoricalState(*tree["store"])
        self.step_num = extras["step"]
        self.lr = float(extras.get("lr", self.lr))
        self.sampler.load_state_dict(_from_jsonable(extras["sampler"]))
        if self.guard is not None:
            # counters don't ride the checkpoint: restart conservative (all
            # rows fresh-at-restore; true staleness is ≤ checkpoint interval)
            self.guard.reset_staleness()
        self._reset_pipeline()
        return True

    # ------------------------------------------------------------- pipeline
    def _batch_pipeline(self) -> SubgraphPipeline:
        """The async batch source, (re)built lazily at the current step."""
        if self._pipeline is None:
            hook = (self.failure_injector.pipeline_hook
                    if isinstance(self.failure_injector, FaultPlan) else None)
            self._pipeline = SubgraphPipeline(
                self.sampler, backend=self.backend,
                depth=self.prefetch if self.prefetch is not None else 0,
                workers=self.pipeline_workers, recycle=self.recycle,
                mode=self.pipeline_mode, start_step=self.step_num,
                build_hook=hook)
        return self._pipeline

    def _reset_pipeline(self) -> None:
        """Close the pipeline; the next step rebuilds it at ``step_num``."""
        if self._pipeline is not None:
            self._pipeline.close()
            self._pipeline = None

    def close(self) -> None:
        """Stop background pipeline workers + checkpoint writer (idempotent)."""
        self._reset_pipeline()
        if self.ckpt is not None:
            self.ckpt.close()

    # ------------------------------------------------------------------ run
    def run(self, num_steps: int, *, eval_every: int = 0) -> list[dict]:
        """Train for ``num_steps`` more steps; returns the history list.

        The supervisor loop: every fault class recovers here without
        operator intervention —

        * simulated preemption → restore the newest verifiable checkpoint
          and continue (the batch pipeline is rebuilt at the restored step,
          so the resumed stream is identical to an uninterrupted run);
        * pipeline-worker crash → rebuild the pipeline at the current step
          and retry the same slot (stream is slot-indexed, so the retry
          fetches the identical batch);
        * divergent step (NaN/Inf/spike, from the health guard) → policy
          ``"rollback"`` (restore + optional lr-backoff) or ``"skip-batch"``
          (drop the poisoned update, advance);
        * checkpoint-write failure → record and continue; the previous
          checkpoint is still intact (atomic publication).

        Consecutive recoveries are bounded by ``max_retries`` — when the
        budget is exhausted without a healthy step in between, the run
        aborts with :class:`TrainingDivergedError` rather than live-locking.
        """
        target = self.step_num + num_steps
        while self.step_num < target:
            try:
                self._one_step()
                self._retries_left = self.max_retries  # healthy step: reset
            except SimulatedPreemption:
                # crash recovery: restore last checkpoint and continue; a
                # failed restore still discards the pipeline so the aborted
                # step's already-consumed batch is re-fetched, not skipped
                restored = self.restore()
                if not restored:
                    self._reset_pipeline()
                self.history.append({"step": self.step_num,
                                     "event": "preemption",
                                     "restored": restored})
                continue
            except PipelineFault as e:
                self._spend_retry(f"pipeline fault: {e}")
                self._reset_pipeline()   # rebuild at step_num: same slot
                self.history.append({"step": self.step_num,
                                     "event": "pipeline-fault",
                                     "error": str(e)})
                continue
            except _Divergence as e:
                self._spend_retry(f"divergence: {e}")
                self._recover_divergence(str(e))
                continue
            if self.ckpt and self.step_num % self.ckpt_every == 0:
                try:
                    self.save()
                except OSError as e:   # includes injected CheckpointWriteFault
                    self.history.append({"step": self.step_num,
                                         "event": "ckpt-write-failed",
                                         "error": str(e)})
            if eval_every and self.step_num % eval_every == 0:
                self.history.append({"step": self.step_num,
                                     "val_acc": float(self.eval("val"))})
        return self.history

    def _spend_retry(self, reason: str) -> None:
        """Consume one unit of the recovery budget or abort the run."""
        self._retries_left -= 1
        if self._retries_left < 0:
            raise TrainingDivergedError(
                f"recovery budget exhausted ({self.max_retries} retries) "
                f"at step {self.step_num}; last incident: {reason}")

    def _recover_divergence(self, reason: str) -> None:
        """Execute the health policy for a rejected (never-applied) step."""
        policy = self.health.policy if self.health else "skip-batch"
        if policy == "rollback":
            restored = self.restore()
            if restored:
                if self.health.lr_backoff < 1.0:
                    self.lr *= self.health.lr_backoff
                self.history.append({"step": self.step_num,
                                     "event": "health-rollback",
                                     "reason": reason, "lr": self.lr})
                return
            # nothing verifiable to roll back to: degrade to skip-batch
        # skip-batch: the poisoned update was never applied; advance past
        # the consumed batch (legacy path: the sampler RNG already moved)
        self.step_num += 1
        if self.guard is not None:
            # the store kept its old rows — every row ages one step
            self.guard.staleness += 1
        self.history.append({"step": self.step_num,
                             "event": "health-skip-batch", "reason": reason,
                             "policy": policy})

    def _one_step(self) -> None:
        t0 = time.time()
        if self._use_pipeline:
            batch = next(self._batch_pipeline())   # may raise PipelineFault
        else:
            sg = self.sampler.sample()
            batch = to_device_batch(sg, backend=self.backend)
        if self.failure_injector is not None:
            self.failure_injector.maybe_fail(self.step_num)
            if isinstance(self.failure_injector, FaultPlan):
                batch = self.failure_injector.corrupt_batch(self.step_num,
                                                            batch)
        loss, grads, new_store, metrics = self._step(
            self.params, self.store, batch, self.data.x, self.data.self_w)
        new_params, new_opt, gnorm = self._update(
            grads, self.opt_state, self.params, jnp.float32(self.lr))
        lossf, gnormf = float(loss), float(gnorm)

        # ---- health gate: nothing below is applied if this step diverged
        if self.guard is not None:
            reason = self.guard.check_step(lossf, gnormf)
            if reason is None and self.guard.store_check_due(self.step_num):
                reason = self.guard.check_store(
                    HistoricalState(*new_store)
                    if not isinstance(new_store, HistoricalState)
                    else new_store)
            if reason is not None:
                raise _Divergence(reason)

        self.params, self.opt_state = new_params, new_opt
        dt = time.time() - t0
        # straggler mitigation: drop the (stale-tolerant) store update when
        # this step blew its deadline, so the next step isn't gated on it
        med = float(np.median(self._step_times)) if self._step_times else dt
        is_straggler = (len(self._step_times) >= 8
                        and dt > self.straggler_deadline * med)
        store_updated = not (is_straggler
                             and self.straggler_policy == "skip-store")
        if store_updated:
            self.store = new_store
        rec = {"step": self.step_num + 1, "loss": lossf,
               "train_acc": float(metrics["train_acc"]),
               "grad_norm": gnormf, "time_s": dt,
               "straggler": bool(is_straggler)}
        if self.guard is not None:
            self.guard.observe(lossf)
            # one fused device->host transfer for the staleness bookkeeping
            # (4 separate np.asarray syncs measurably inflate the step)
            bg, bm, hg, hm = jax.device_get(
                (batch.batch_gids, batch.batch_mask,
                 batch.halo_gids, batch.halo_mask))
            halo_stale = self.guard.halo_staleness(hg, hm)
            self.guard.tick(bg, bm, store_updated)
            rec["halo_staleness"] = halo_stale
            rho_msg = self.guard.check_rho_budget(halo_stale)
            if rho_msg is not None:
                rec["staleness_violation"] = rho_msg
        self._step_times.append(dt)
        self.step_num += 1
        self.history.append(rec)

    # ----------------------------------------------------------------- eval
    def eval(self, split: str = "val") -> float:
        """Full-graph accuracy on the given split ("train"|"val"|"test")."""
        mask = {"val": self.graph.val_mask, "test": self.graph.test_mask,
                "train": self.graph.train_mask}[split]
        return accuracy(self.gnn, self.params, self.data,
                        jnp.asarray(mask.astype(np.float32)))


def _as_pspec_tree(params):
    from repro.models.spec import PSpec
    return jax.tree.map(
        lambda p: PSpec(tuple(p.shape), (None,) * p.ndim, dtype=p.dtype),
        params)


def _jsonable(state: dict):
    import json
    return json.loads(json.dumps(state, default=_np_default))


def _np_default(o):
    if isinstance(o, np.integer):
        return int(o)
    if isinstance(o, np.floating):
        return float(o)
    if isinstance(o, np.ndarray):
        return {"__nd__": o.tolist(), "dtype": str(o.dtype)}
    raise TypeError(type(o))


def _from_jsonable(state):
    def conv(x):
        if isinstance(x, dict):
            if "__nd__" in x:
                return np.asarray(x["__nd__"], dtype=x["dtype"])
            return {k: conv(v) for k, v in x.items()}
        if isinstance(x, list):
            return [conv(v) for v in x]
        return x
    return conv(state)
