"""Fault-tolerant training loop for the paper's workload (GNN + LMC).

Production behaviors implemented (and tested in tests/test_fault_tolerance.py):
  * periodic atomic checkpoints of (params, opt state, historical stores,
    sampler RNG state, step counter);
  * crash/preemption recovery: on failure the loop restores the latest
    checkpoint and continues — the FailureInjector simulates preemptions;
  * straggler mitigation: a per-step deadline (k × running-median step time);
    a straggler step's *store updates* can be dropped without violating LMC's
    convergence assumptions (staleness is bounded by Thm 2's ρ-term — see
    DESIGN.md §4), which is what `straggler_policy="skip-store"` does;
  * deterministic resume: the sampler's bit-generator state rides along.

``backend="ell"`` switches the jit'd step onto the Pallas bucketed-ELL
SpMM/compensate kernels (compiled on TPU, interpreter fallback on CPU);
batches are then built with their adjacency re-bucketed host-side
(`to_device_batch(sg, backend="ell")`).

``prefetch``/``recycle`` route batch construction through the async
``SubgraphPipeline`` (repro.data.prefetch, DESIGN.md §9): sampling + ELL
bucketing move to background threads, host→device transfers double-buffer
behind the step, and each subgraph can be recycled for ρ consecutive steps.
The pipeline stream is a pure function of (sampler seed, step index), so
checkpoint resume stays deterministic — the pipeline is simply rebuilt at
the restored step. The default (``prefetch=None, recycle=1``) keeps the
legacy synchronous, stateful-RNG path byte-for-byte.
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core import (HistoricalState, MBMethod, from_graph, accuracy,
                        init_history, make_train_step, to_device_batch)
from repro.data.prefetch import SubgraphPipeline
from repro.graph import ClusterSampler
from repro.models.gnn import GNN
from repro.optim.optimizers import Optimizer


class FailureInjector:
    """Deterministic simulated preemptions for fault-tolerance tests."""

    def __init__(self, fail_at_steps: tuple = ()):  # global step indices
        self.fail_at = set(fail_at_steps)
        self.fired: set = set()

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"simulated preemption at step {step}")


class GNNTrainer:
    """Orchestrates sampling, the jit'd LMC step, optimizer updates,
    checkpointing and fault handling for one training run.

    Not thread-safe: one trainer per (single) training thread; background
    work (batch construction) is delegated to ``SubgraphPipeline`` workers
    when ``prefetch``/``recycle`` are set. Call :meth:`close` (or drop the
    trainer) to stop those workers.
    """

    def __init__(self, gnn: GNN, method: MBMethod, graph, sampler: ClusterSampler,
                 optimizer: Optimizer, *, ckpt_dir: Optional[str] = None,
                 ckpt_every: int = 50, seed: int = 0,
                 failure_injector: Optional[FailureInjector] = None,
                 straggler_deadline: float = 4.0,
                 straggler_policy: str = "skip-store",
                 backend: str = "segment",
                 stream: Optional[bool] = None,
                 prefetch: Optional[int] = None,
                 recycle: int = 1,
                 pipeline_workers: int = 2,
                 pipeline_mode: str = "uniform"):
        """Build the jit'd step and (lazily) the batch pipeline.

        Args:
            gnn / method / graph / sampler / optimizer: the model, the
                mini-batch method config (LMC/GAS/...), the host graph, its
                cluster sampler and the optimizer.
            ckpt_dir / ckpt_every: enable periodic atomic checkpoints.
            seed: parameter-init PRNG seed.
            failure_injector: deterministic simulated preemptions (tests).
            straggler_deadline / straggler_policy: per-step deadline as a
                multiple of the running-median step time; ``"skip-store"``
                drops a straggler step's store update (Thm 2-safe).
            backend: aggregation hot path, ``"segment"`` | ``"ell"``.
            stream: HBM→VMEM DMA gather knob for the ell kernels
                (None = autodetect).
            prefetch: queue depth of the async batch pipeline. ``None``
                (default) keeps the legacy synchronous stateful-RNG path;
                ``0`` uses the pipeline's schedule-indexed stream but builds
                synchronously (debugging / equality tests); ``>= 1`` builds
                ahead on background threads with double-buffered transfers.
            recycle: reuse each sampled subgraph for this many consecutive
                steps (ρ; implies the pipeline path when > 1).
            pipeline_workers: builder threads when prefetching.
            pipeline_mode: schedule of the pipeline path — ``"uniform"``
                (iid cluster draws, Alg. 1 line 4) or ``"epoch"`` (shuffled
                epochs: every cluster exactly once per B/c distinct slots).
        """
        self.gnn = gnn
        self.method = method
        self.graph = graph
        self.sampler = sampler
        self.opt = optimizer
        self.data = from_graph(graph)
        self.failure_injector = failure_injector
        self.straggler_deadline = straggler_deadline
        self.straggler_policy = straggler_policy
        self.backend = backend  # aggregation hot path: "segment" | "ell"
        self.stream = stream    # HBM→VMEM DMA gather knob (None: autodetect)
        if recycle < 1:
            raise ValueError(f"recycle must be >= 1, got {recycle}")
        self.prefetch = prefetch
        self.recycle = int(recycle)
        self.pipeline_workers = int(pipeline_workers)
        self.pipeline_mode = pipeline_mode
        # pipeline path whenever asked for (prefetch set) or needed (ρ > 1);
        # built lazily so it always starts at the current step (resume-safe)
        self._use_pipeline = prefetch is not None or self.recycle > 1
        self._pipeline: Optional[SubgraphPipeline] = None

        self.params = gnn.init_params(jax.random.key(seed))
        pspec = jax.eval_shape(lambda: self.params)  # shapes only
        self.opt_state = optimizer.init(self.params, _as_pspec_tree(self.params))
        self.store = init_history(gnn.num_layers, graph.num_nodes,
                                  gnn.hidden_dim)
        self.step_num = 0
        # no buffer donation: the straggler skip-store policy and elastic
        # rescale both need the pre-step store to stay alive
        self._step = jax.jit(make_train_step(gnn, method, graph.num_nodes,
                                             backend=backend, stream=stream))
        self._update = jax.jit(
            lambda g, s, p: optimizer.update(g, s, p, optimizer.lr))
        self.ckpt = CheckpointManager(ckpt_dir) if ckpt_dir else None
        self.ckpt_every = ckpt_every
        self._step_times: list[float] = []
        self.history: list[dict] = []

    # ----------------------------------------------------------------- state
    def _state_tree(self):
        return {"params": self.params, "opt": self.opt_state,
                "store": tuple(self.store)}

    def save(self) -> None:
        """Write an atomic checkpoint (params/opt/stores/sampler RNG/step)."""
        if self.ckpt is None:
            return
        extras = {"step": self.step_num,
                  "sampler": _jsonable(self.sampler.state_dict())}
        self.ckpt.save(self.step_num, self._state_tree(), extras)

    def restore(self) -> bool:
        """Restore the latest checkpoint; returns False when none exists.

        Also discards any in-flight batch pipeline: the stream is a pure
        function of the step index, so rebuilding it at the restored step
        replays exactly the batches the uninterrupted run would have seen.
        """
        if self.ckpt is None or self.ckpt.latest_step() is None:
            return False
        tree, extras, step = self.ckpt.restore(self._state_tree())
        self.params = tree["params"]
        self.opt_state = tree["opt"]
        self.store = HistoricalState(*tree["store"])
        self.step_num = extras["step"]
        self.sampler.load_state_dict(_from_jsonable(extras["sampler"]))
        self._reset_pipeline()
        return True

    # ------------------------------------------------------------- pipeline
    def _batch_pipeline(self) -> SubgraphPipeline:
        """The async batch source, (re)built lazily at the current step."""
        if self._pipeline is None:
            self._pipeline = SubgraphPipeline(
                self.sampler, backend=self.backend,
                depth=self.prefetch if self.prefetch is not None else 0,
                workers=self.pipeline_workers, recycle=self.recycle,
                mode=self.pipeline_mode, start_step=self.step_num)
        return self._pipeline

    def _reset_pipeline(self) -> None:
        """Close the pipeline; the next step rebuilds it at ``step_num``."""
        if self._pipeline is not None:
            self._pipeline.close()
            self._pipeline = None

    def close(self) -> None:
        """Stop background pipeline workers (idempotent)."""
        self._reset_pipeline()

    # ------------------------------------------------------------------ run
    def run(self, num_steps: int, *, eval_every: int = 0) -> list[dict]:
        """Train for ``num_steps`` more steps; returns the history list.

        Handles simulated preemptions by restoring the latest checkpoint and
        continuing (the batch pipeline, when in use, is rebuilt at the
        restored step so the resumed stream is identical).
        """
        target = self.step_num + num_steps
        while self.step_num < target:
            try:
                self._one_step()
            except RuntimeError as e:
                if "simulated preemption" not in str(e):
                    raise
                # crash recovery: restore last checkpoint and continue; a
                # failed restore still discards the pipeline so the aborted
                # step's already-consumed batch is re-fetched, not skipped
                restored = self.restore()
                if not restored:
                    self._reset_pipeline()
                self.history.append({"step": self.step_num,
                                     "event": "preemption",
                                     "restored": restored})
                continue
            if self.ckpt and self.step_num % self.ckpt_every == 0:
                self.save()
            if eval_every and self.step_num % eval_every == 0:
                self.history.append({"step": self.step_num,
                                     "val_acc": float(self.eval("val"))})
        return self.history

    def _one_step(self) -> None:
        t0 = time.time()
        if self._use_pipeline:
            batch = next(self._batch_pipeline())
        else:
            sg = self.sampler.sample()
            batch = to_device_batch(sg, backend=self.backend)
        if self.failure_injector is not None:
            self.failure_injector.maybe_fail(self.step_num)
        loss, grads, new_store, metrics = self._step(
            self.params, self.store, batch, self.data.x, self.data.self_w)
        self.params, self.opt_state, gnorm = self._update(
            grads, self.opt_state, self.params)
        dt = time.time() - t0
        # straggler mitigation: drop the (stale-tolerant) store update when
        # this step blew its deadline, so the next step isn't gated on it
        med = float(np.median(self._step_times)) if self._step_times else dt
        is_straggler = (len(self._step_times) >= 8
                        and dt > self.straggler_deadline * med)
        if not (is_straggler and self.straggler_policy == "skip-store"):
            self.store = new_store
        self._step_times.append(dt)
        self.step_num += 1
        self.history.append({"step": self.step_num, "loss": float(loss),
                             "train_acc": float(metrics["train_acc"]),
                             "grad_norm": float(gnorm),
                             "time_s": dt, "straggler": bool(is_straggler)})

    # ----------------------------------------------------------------- eval
    def eval(self, split: str = "val") -> float:
        """Full-graph accuracy on the given split ("train"|"val"|"test")."""
        mask = {"val": self.graph.val_mask, "test": self.graph.test_mask,
                "train": self.graph.train_mask}[split]
        return accuracy(self.gnn, self.params, self.data,
                        jnp.asarray(mask.astype(np.float32)))


def _as_pspec_tree(params):
    from repro.models.spec import PSpec
    return jax.tree.map(
        lambda p: PSpec(tuple(p.shape), (None,) * p.ndim, dtype=p.dtype),
        params)


def _jsonable(state: dict):
    import json
    return json.loads(json.dumps(state, default=_np_default))


def _np_default(o):
    if isinstance(o, np.integer):
        return int(o)
    if isinstance(o, np.floating):
        return float(o)
    if isinstance(o, np.ndarray):
        return {"__nd__": o.tolist(), "dtype": str(o.dtype)}
    raise TypeError(type(o))


def _from_jsonable(state):
    def conv(x):
        if isinstance(x, dict):
            if "__nd__" in x:
                return np.asarray(x["__nd__"], dtype=x["dtype"])
            return {k: conv(v) for k, v in x.items()}
        if isinstance(x, list):
            return [conv(v) for v in x]
        return x
    return conv(state)
