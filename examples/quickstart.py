"""Quickstart: LMC vs GAS vs Cluster-GCN on a synthetic ogbn-arxiv-like graph.

Trains the paper's GCN with each mini-batch method for a few hundred steps and
prints the validation-accuracy trajectory — the minimal version of the paper's
Figure 2.

    PYTHONPATH=src python examples/quickstart.py [--steps 300]
"""
import argparse

from repro.core import METHODS
from repro.graph import ClusterSampler, make_sbm_dataset, partition_graph
from repro.models import make_gnn
from repro.optim import sgd
from repro.train import GNNTrainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--preset", default="arxiv-cpu")
    args = ap.parse_args()

    g = make_sbm_dataset(args.preset, seed=0)
    parts = partition_graph(g, 32, seed=0)
    print(f"graph: {g.num_nodes} nodes, {g.num_edges} directed edges, "
          f"{g.num_classes} classes")

    for name in ("lmc", "gas", "cluster"):
        m = METHODS[name]
        gnn = make_gnn("gcn", g.feature_dim, 128, g.num_classes, 2)
        sampler = ClusterSampler(g, 32, 4, parts=parts, seed=1,
                                 include_halo=m.include_halo,
                                 edge_weight_mode=m.edge_weight_mode)
        tr = GNNTrainer(gnn, m, g, sampler, sgd(lr=0.3), seed=0)
        print(f"\n=== {name} ===")
        for k in range(args.steps // 50):
            tr.run(50)
            print(f"  step {tr.step_num:4d}  "
                  f"loss {tr.history[-1]['loss']:.3f}  "
                  f"val acc {float(tr.eval('val')):.3f}")
        print(f"  final test acc: {float(tr.eval('test')):.3f}")


if __name__ == "__main__":
    main()
