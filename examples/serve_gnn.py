"""GNN inference serving over the LMC historical store (DESIGN.md §12).

Trains the paper's GCN briefly with LMC, warms an exact embedding store from
the trained params, then serves paced classification requests through
``repro.serve.GNNServer``: bounded admission queue, padded-shape bucket
batches, deadlines, and the exact→ti degradation ladder. ``--fault`` turns
on the serving fault drills (slow batch / poisoned store rows / worker
crash / queue-overflow burst) to watch the typed recovery paths fire.

    PYTHONPATH=src python examples/serve_gnn.py --requests 64 --qps 100
    PYTHONPATH=src python examples/serve_gnn.py --fault --requests 64
"""
import argparse
import time
from collections import Counter

import numpy as np

from repro.core import LMC
from repro.graph import ClusterSampler, make_sbm_dataset, partition_graph
from repro.models import make_gnn
from repro.optim import sgd
from repro.serve import GNNServer, ServeConfig
from repro.train import GNNTrainer
from repro.train.health import FaultPlan


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="ppi-cpu")
    ap.add_argument("--train-steps", type=int, default=100)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--qps", type=float, default=100.0)
    ap.add_argument("--max-targets", type=int, default=16)
    ap.add_argument("--backend", default="segment",
                    choices=("segment", "ell"))
    ap.add_argument("--deadline-s", type=float, default=2.0)
    ap.add_argument("--fault", action="store_true",
                    help="inject the serving fault classes mid-run")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    g = make_sbm_dataset(args.preset, seed=args.seed)
    print(f"graph: {g.num_nodes} nodes, {g.num_edges} directed edges, "
          f"{g.num_classes} classes")
    gnn = make_gnn("gcn", g.feature_dim, 64, g.num_classes, 3)
    parts = partition_graph(g, 16, seed=0)
    sampler = ClusterSampler(g, 16, 2, parts=parts, seed=1)
    tr = GNNTrainer(gnn, LMC, g, sampler, sgd(lr=0.3), seed=args.seed)
    tr.run(args.train_steps)
    print(f"trained {args.train_steps} steps: "
          f"loss {tr.history[-1]['loss']:.3f}  "
          f"val acc {float(tr.eval('val')):.3f}")

    plan = None
    if args.fault:
        # Batch seqs run behind request indices (the batcher coalesces), so
        # schedule the batch-keyed faults early; the burst is request-keyed.
        plan = FaultPlan(serve_slow_at=(2,), serve_slow_s=0.5,
                         serve_poison_at=(4,),
                         serve_crash_at=(6,),
                         serve_burst_at=(args.requests // 2,),
                         serve_burst_n=48)

    cfg = ServeConfig(backend=args.backend,
                      default_deadline_s=args.deadline_s,
                      warmup=True)
    srv = GNNServer(gnn, g, tr.params, config=cfg, fault_plan=plan,
                    data=tr.data)
    print(f"server up: buckets {cfg.buckets}, queue depth {cfg.queue_depth}, "
          f"backend {cfg.backend}")

    rng = np.random.default_rng(args.seed)
    period = 1.0 / max(args.qps, 1e-9)
    futs = []
    t0 = time.time()
    for i in range(args.requests):
        n = int(rng.integers(1, args.max_targets + 1))
        nodes = rng.choice(g.num_nodes, size=n, replace=False)
        futs.append(srv.submit(nodes, request_id=f"r{i}"))
        if plan is not None:
            for j in range(plan.serve_burst(i)):
                futs.append(srv.submit(
                    rng.choice(g.num_nodes, size=4, replace=False),
                    request_id=f"burst{i}.{j}"))
        time.sleep(max(0.0, t0 + (i + 1) * period - time.time()))
    responses = [f.result(timeout=args.deadline_s + 60.0) for f in futs]
    wall = time.time() - t0

    lat = np.array([r.latency_s for r in responses if r.ok])
    counts = Counter(r.status for r in responses)
    print(f"\n{len(responses)} responses in {wall:.2f}s "
          f"({len(responses) / wall:.1f} rps)")
    print("status:", dict(sorted(counts.items())))
    if lat.size:
        print(f"latency p50 {np.percentile(lat, 50) * 1e3:.1f}ms  "
              f"p99 {np.percentile(lat, 99) * 1e3:.1f}ms")
    for r in responses:
        if r.status == "degraded":
            print(f"  degraded {r.request_id}: {r.degraded_reason}")
            break
    if srv.events:
        kinds = Counter(e["kind"] for e in srv.events)
        print("server events:", dict(sorted(kinds.items())))
    drained = srv.drain()
    st = srv.stats()
    print(f"drain clean: {drained}  pending after drain: {st['pending']}  "
          f"breaker: {st['breaker']}  "
          f"worker restarts: {st.get('worker_restarts', 0)}")


if __name__ == "__main__":
    main()
