"""Batched-request LM serving: prefill a batch of prompts, then decode with
the per-arch KV/recurrent caches (the serve_step the decode_* dry-run shapes
lower). Runs a reduced config of any assigned architecture on CPU.

    PYTHONPATH=src python examples/serve_decode.py --arch rwkv6-7b --tokens 24
    PYTHONPATH=src python examples/serve_decode.py --arch deepseek-v2-lite-16b
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_NAMES, reduced_config
from repro.models.lm import LM


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=ARCH_NAMES)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    args = ap.parse_args()

    cfg = reduced_config(args.arch)
    lm = LM(cfg)
    params = lm.init_params(jax.random.key(0))
    mem = None
    if cfg.family in ("vlm", "encdec"):
        t = cfg.frontend_tokens or 16
        mem = (jax.random.normal(jax.random.key(1),
                                 (args.batch, t, cfg.d_model)) * 0.05
               ).astype(jnp.bfloat16)

    prompts = jax.random.randint(jax.random.key(2),
                                 (args.batch, args.prompt_len), 0, cfg.vocab)
    prefill = jax.jit(lambda p, t: lm.prefill(p, t, args.max_seq, mem))
    decode = jax.jit(lambda p, c, t, n: lm.decode_step(p, c, t, n, mem))

    t0 = time.time()
    logits, caches = prefill(params, prompts)
    jax.block_until_ready(logits)
    print(f"prefill {args.batch}x{args.prompt_len}: {time.time()-t0:.2f}s")

    toks = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    out = [toks]
    t0 = time.time()
    for i in range(args.tokens - 1):
        logits, caches = decode(params, caches, toks,
                                jnp.int32(args.prompt_len + i))
        toks = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        out.append(toks)
    jax.block_until_ready(toks)
    dt = time.time() - t0
    gen = np.concatenate([np.asarray(t) for t in out], axis=1)
    print(f"decoded {args.tokens} tokens/seq in {dt:.2f}s "
          f"({args.tokens*args.batch/max(dt,1e-9):.1f} tok/s total)")
    for b in range(args.batch):
        print(f"  seq{b}: {gen[b].tolist()}")


if __name__ == "__main__":
    main()
