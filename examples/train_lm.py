"""Train a (reduced) assigned LM architecture on the synthetic token stream —
the LM-side end-to-end driver: data pipeline -> train step (microbatching,
clipping, optimizer) -> loss curve.

    PYTHONPATH=src python examples/train_lm.py --arch llama3.2-1b --steps 60
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_NAMES, reduced_config
from repro.data import TokenStream
from repro.launch.steps import make_lm_train_step
from repro.models.lm import LM
from repro.optim import make_optimizer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=ARCH_NAMES)
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = reduced_config(args.arch)
    lm = LM(cfg)
    params = lm.init_params(jax.random.key(0))
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"{args.arch} (reduced): {n_params/1e6:.1f}M params, "
          f"optimizer={cfg.optimizer}")

    opt = make_optimizer(cfg.optimizer, lr=3e-3)
    opt_state = opt.init(params, lm.params_spec())
    step = jax.jit(make_lm_train_step(lm, opt))
    stream = TokenStream(cfg.vocab, args.batch, args.seq, seed=0)

    mem = None
    if cfg.family in ("vlm", "encdec"):
        t = cfg.frontend_tokens or 16
        mem = (jax.random.normal(jax.random.key(1),
                                 (args.batch, t, cfg.d_model)) * 0.05
               ).astype(jnp.bfloat16)

    t0 = time.time()
    for i in range(args.steps):
        batch = next(stream)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        if mem is not None:
            batch["memory"] = mem
        params, opt_state, m = step(params, opt_state, batch)
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {float(m['loss']):.4f}  "
                  f"gnorm {float(m['grad_norm']):.3f}  "
                  f"({time.time()-t0:.1f}s)")
    print("loss should decrease from ~ln(vocab) as the model memorizes the "
          "Zipf/markov stream")


if __name__ == "__main__":
    main()
