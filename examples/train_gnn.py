"""End-to-end training driver: LMC-GCNII on a full-scale synthetic dataset
with checkpointing, fault tolerance, the Pallas-kernel aggregation path and
periodic evaluation — the production loop the paper's Table 1/2 workflow maps
onto.

    PYTHONPATH=src python examples/train_gnn.py --steps 400 --preset arxiv-cpu
    PYTHONPATH=src python examples/train_gnn.py --preset arxiv-like   # 169k nodes
    PYTHONPATH=src python examples/train_gnn.py --backend ell  # Pallas SpMM/
        # compensate kernels on the hot path (compiled on TPU, interpreted on CPU)
    PYTHONPATH=src python examples/train_gnn.py --backend ti
        # store-free message-invariance compensation (zero historical-store
        # reads/writes on the hot path; DESIGN.md §11)
    PYTHONPATH=src python examples/train_gnn.py --prefetch 4 --recycle 4
        # async sampling pipeline + minibatch recycling (DESIGN.md §9)
    PYTHONPATH=src python examples/train_gnn.py --no-prefetch
        # legacy synchronous sampling (stateful sampler RNG)
    PYTHONPATH=src python examples/train_gnn.py --health --async-ckpt
        # numerical-health supervisor (NaN/spike guard with rollback) +
        # background checkpoint writes (DESIGN.md §10)
"""
import argparse
import time

from repro.core import METHODS
from repro.graph import ClusterSampler, make_sbm_dataset, partition_graph
from repro.models import make_gnn
from repro.optim import sgd
from repro.train import GNNTrainer, HealthConfig


def main():
    ap = argparse.ArgumentParser(
        description="End-to-end LMC GNN training on a synthetic full-scale "
                    "dataset (checkpointing, fault tolerance, Pallas kernel "
                    "path, async sampling pipeline)")
    ap.add_argument("--steps", type=int, default=400,
                    help="total train steps (resumes from checkpoint if any)")
    ap.add_argument("--preset", default="arxiv-cpu",
                    help="synthetic dataset preset, e.g. arxiv-cpu (4k nodes) "
                         "or arxiv-like (169k); see repro.graph.synthetic."
                         "DATASET_PRESETS")
    ap.add_argument("--arch", default="gcnii", choices=["gcn", "gcnii",
                                                        "sage", "gin"],
                    help="GNN architecture")
    ap.add_argument("--method", default=None, choices=list(METHODS),
                    help="mini-batch method: lmc, gas, cluster, ti, or the "
                         "compensation ablations (default: ti when "
                         "--backend ti, else lmc)")
    ap.add_argument("--hidden", type=int, default=128,
                    help="hidden width of every GNN layer")
    ap.add_argument("--layers", type=int, default=4,
                    help="number of GNN layers")
    ap.add_argument("--parts", type=int, default=32,
                    help="graph partition count B (clusters)")
    ap.add_argument("--clusters-per-batch", type=int, default=4,
                    help="clusters c sampled per mini-batch (Alg. 1 line 4)")
    ap.add_argument("--backend", default="segment",
                    choices=["segment", "ell", "ti"],
                    help="aggregation/compensation hot path: jnp segment-sum, "
                         "the Pallas bucketed-ELL SpMM/compensate kernels "
                         "(compiled on TPU, interpreter fallback on CPU), or "
                         "ti = ELL aggregation + store-free message-"
                         "invariance compensation (zero historical-store "
                         "reads; DESIGN.md §11)")
    ap.add_argument("--stream", default=None, action="store_true",
                    help="force the HBM→VMEM double-buffered DMA gather in "
                         "the ell-backend kernels (default: autodetect = "
                         "streamed; required for full-graph stores on TPU)")
    ap.add_argument("--no-stream", dest="stream", action="store_false",
                    help="force the legacy resident VMEM gather blocks "
                         "(small graphs only)")
    ap.add_argument("--prefetch", type=int, default=2, metavar="N",
                    help="async sampling pipeline queue depth: background "
                         "threads build + bucket the next N batches while "
                         "the device steps, with double-buffered "
                         "host->device transfer (DESIGN.md §9); 0 keeps the "
                         "schedule-indexed stream but builds synchronously")
    ap.add_argument("--no-prefetch", dest="prefetch", action="store_const",
                    const=None,
                    help="fall back to the legacy fully synchronous sampling "
                         "path (stateful sampler RNG, no pipeline)")
    ap.add_argument("--recycle", type=int, default=1, metavar="R",
                    help="minibatch recycling: reuse each sampled subgraph "
                         "for R consecutive steps before resampling "
                         "(LazyGNN-style; staleness stays within LMC's "
                         "Thm 2 bound — see DESIGN.md §9)")
    ap.add_argument("--pipeline-workers", type=int, default=2, metavar="W",
                    help="builder threads for the sampling pipeline")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_gnn_ckpt",
                    help="checkpoint directory (delete it for a fresh run)")
    ap.add_argument("--health", action="store_true",
                    help="enable the numerical-health guard (NaN/Inf + "
                         "loss-spike checks with staleness accounting, "
                         "DESIGN.md §10)")
    ap.add_argument("--health-policy", default="rollback",
                    choices=["rollback", "skip-batch"],
                    help="recovery policy on a divergent step: roll back to "
                         "the newest verifiable checkpoint, or drop the "
                         "poisoned update and continue")
    ap.add_argument("--lr-backoff", type=float, default=1.0, metavar="F",
                    help="multiply the lr by F on every health rollback "
                         "(1.0 = keep lr)")
    ap.add_argument("--max-retries", type=int, default=3, metavar="N",
                    help="consecutive recovery actions (rollbacks / skips / "
                         "pipeline rebuilds) allowed before the run aborts "
                         "with TrainingDivergedError")
    ap.add_argument("--async-ckpt", action="store_true",
                    help="write checkpoints on a background thread (the "
                         "train step only pays the device->host snapshot; "
                         "files are byte-identical to synchronous saves)")
    args = ap.parse_args()
    if args.prefetch is None and args.recycle > 1:
        ap.error("--no-prefetch is incompatible with --recycle > 1 "
                 "(recycling needs the schedule-indexed pipeline)")

    t0 = time.time()
    g = make_sbm_dataset(args.preset, seed=0)
    parts = partition_graph(g, args.parts, seed=0)
    print(f"[{time.time()-t0:6.1f}s] graph {g.num_nodes}n/{g.num_edges}e, "
          f"partitioned into {args.parts}")

    if args.method is None:
        args.method = "ti" if args.backend == "ti" else "lmc"
    m = METHODS[args.method]
    gnn = make_gnn(args.arch, g.feature_dim, args.hidden, g.num_classes,
                   args.layers)
    sampler = ClusterSampler(g, args.parts, args.clusters_per_batch,
                             parts=parts, seed=1,
                             include_halo=m.include_halo,
                             edge_weight_mode=m.edge_weight_mode)
    health = (HealthConfig(policy=args.health_policy,
                           lr_backoff=args.lr_backoff)
              if args.health else None)
    tr = GNNTrainer(gnn, m, g, sampler, sgd(lr=0.2), seed=0,
                    ckpt_dir=args.ckpt_dir, ckpt_every=100,
                    backend=args.backend, stream=args.stream,
                    prefetch=args.prefetch, recycle=args.recycle,
                    pipeline_workers=args.pipeline_workers,
                    health=health, max_retries=args.max_retries,
                    async_ckpt=args.async_ckpt)
    if tr.restore():
        print(f"resumed from checkpoint at step {tr.step_num}")

    while tr.step_num < args.steps:
        tr.run(50)
        h = tr.history[-1]
        print(f"[{time.time()-t0:6.1f}s] step {tr.step_num:5d} "
              f"loss {h['loss']:.4f} train_acc {h['train_acc']:.3f} "
              f"val {float(tr.eval('val')):.3f}")
    tr.save()
    tr.close()   # stop pipeline workers
    print(f"done: test acc {float(tr.eval('test')):.4f}; "
          f"checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
