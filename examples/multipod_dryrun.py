"""Multi-pod dry-run example: lower + compile one cell on the 512-chip mesh
and print its memory/cost/collective analysis.

    PYTHONPATH=src python examples/multipod_dryrun.py --arch llama3.2-1b \
        --shape train_4k
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--single-pod", action="store_true")
    args = ap.parse_args()
    from repro.launch.dryrun import run_cell
    res = run_cell(args.arch, args.shape, multi_pod=not args.single_pod)
    print("\nresult:", {k: v for k, v in res.items() if k != "trace"})


if __name__ == "__main__":
    main()
