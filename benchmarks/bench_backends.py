"""Backend head-to-head (ISSUE 9) -> BENCH_backends.json.

Races the three aggregation/compensation backends of ``make_train_step``
through the same sampler stream on the synthetic benchmark graph:

* ``segment`` — jnp segment-sum aggregation + store gather/lerp compensation;
* ``ell``     — Pallas bucketed-ELL SpMM + fused ``lmc_compensate`` kernel;
* ``ti``      — same Pallas SpMM, but the store-free message-invariance
                compensation (DESIGN.md §11): an elementwise α-rescale of the
                in-batch fresh values, zero historical-store reads or writes.

Per backend the artifact records:

* ``us_per_call``        — best-of-iters jitted step time over a fixed epoch
                           of prebuilt device batches (same protocol as the
                           kernel micro-benchmarks);
* ``loss_mid`` / ``loss_final`` — SGD training loss at the halfway point and
                           the mean over the last 10 of ``steps`` steps, all
                           backends from identical params/sampler streams
                           (the convergence head-to-head);
* ``store_read_bytes_per_step`` / ``store_write_bytes_per_step`` — analytic
                           historical-store traffic: LMC gathers NH store
                           rows per layer in both directions ((2L-1) reads)
                           and refreshes NB rows ((2L-1) writes); ti moves
                           zero store bytes and only touches the (NH,) α
                           vector per compensation site.

``ti_vs_ell`` carries the two cross-backend tripwires `scripts/check.sh`
gates: ``step_ratio`` (ti does strictly less memory traffic than ell, so its
step must stay <= 1.0x) and ``loss_rel_gap`` (terminal-loss agreement;
``gate`` marks full-fidelity runs — fast runs record it without enforcing).

Run: ``PYTHONPATH=src python -m benchmarks.bench_backends [--fast]`` or via
``python -m benchmarks.run --only backends``.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

ROOT = Path(__file__).resolve().parents[1]
OUT = ROOT / "experiments" / "bench"

CFG = dict(preset="ppi-cpu", hidden=64, layers=3, parts=16, c=2, lr=0.2)
_METHOD_OF = {"segment": "lmc", "ell": "lmc", "ti": "ti"}


def _timer(fn, iters=3):
    """Best-of-iters per-call time in us (see benchmarks/run.py)."""
    fn()  # warmup/compile
    best = float("inf")
    for _ in range(iters):
        t0 = time.time()
        fn()
        best = min(best, time.time() - t0)
    return best * 1e6


def _store_bytes(backend, method, sg, layers: int,
                 hidden: int) -> tuple[int, int]:
    """Analytic per-step historical-store traffic (bytes read, written).

    There are ``2L-1`` compensation sites (L forward, L-1 backward); a
    store-reading backend gathers NH d-wide f32 rows at each (backend="ti"
    substitutes the in-batch α-rescale and reads nothing), and a
    store-writing method scatters NB rows back at each.
    """
    sites = 2 * layers - 1
    reads = sites * sg.n_halo * hidden * 4 \
        if backend != "ti" and method.fwd_mode in ("lmc", "historical") else 0
    writes = sites * sg.n_batch * hidden * 4 if method.store_writes else 0
    return reads, writes


def bench_backends(fast: bool = False) -> dict:
    import jax
    from repro.core import (METHODS, from_graph, init_history,
                            make_train_step, to_device_batch)
    from repro.graph import ClusterSampler, make_sbm_dataset, partition_graph
    from repro.models import make_gnn

    g = make_sbm_dataset(CFG["preset"], seed=3)
    parts = partition_graph(g, CFG["parts"], seed=0)
    data = from_graph(g)
    gnn = make_gnn("gcn", g.feature_dim, CFG["hidden"], g.num_classes,
                   CFG["layers"])
    params0 = gnn.init_params(jax.random.key(0))
    steps = 40 if fast else 120
    iters = 3 if fast else 5

    backends = ("segment", "ell", "ti")
    setup = {}
    for backend in backends:
        m = METHODS[_METHOD_OF[backend]]
        s = ClusterSampler(g, CFG["parts"], CFG["c"], parts=parts, seed=1,
                           stochastic=False)
        step = jax.jit(make_train_step(gnn, m, g.num_nodes, backend=backend))
        sgs = list(s.epoch())
        batches = [to_device_batch(sg, backend=backend) for sg in sgs]
        setup[backend] = (m, step, sgs, batches)

    # ---- step time: interleaved rounds, min per backend ------------------
    # Interleaving + best-of is what keeps the ti-vs-ell ratio meaningful on
    # this interpret-mode CPU box, where a single epoch pass jitters by
    # ~15% — far more than the compensate-kernel work ti removes.
    def epoch_pass(backend):
        m, step, _, batches = setup[backend]
        store = init_history(gnn.num_layers, g.num_nodes, gnn.hidden_dim)
        for b in batches:
            _, _, store, _ = step(params0, store, b, data.x, data.self_w)
        jax.block_until_ready(store.h)

    best = {b: float("inf") for b in backends}
    for b in backends:
        epoch_pass(b)                       # warmup/compile
    for _ in range(2 * iters):
        for b in backends:
            t0 = time.time()
            epoch_pass(b)
            best[b] = min(best[b], time.time() - t0)

    rows = {}
    for backend in backends:
        m, step, sgs, batches = setup[backend]
        us = best[backend] * 1e6 / len(batches)

        # ---- convergence: `steps` SGD steps from identical init ----------
        params = params0
        store = init_history(gnn.num_layers, g.num_nodes, gnn.hidden_dim)
        losses = []
        while len(losses) < steps:
            for b in batches:
                if len(losses) >= steps:
                    break
                loss, grads, store, _ = step(params, store, b, data.x,
                                             data.self_w)
                params = jax.tree.map(lambda p, gr: p - CFG["lr"] * gr,
                                      params, grads)
                losses.append(float(loss))
        loss_mid = float(np.mean(losses[steps // 2 - 5:steps // 2 + 5]))
        loss_final = float(np.mean(losses[-10:]))

        reads, writes = _store_bytes(backend, m, sgs[0], CFG["layers"],
                                     CFG["hidden"])
        rows[backend] = {
            "us_per_call": us, "method": m.name,
            "loss_mid": loss_mid, "loss_final": loss_final,
            "store_read_bytes_per_step": reads,
            "store_write_bytes_per_step": writes,
        }
        print(f"backends/{backend},{us:.0f},loss@{steps}={loss_final:.4f};"
              f"store_rw_bytes={reads}+{writes}", flush=True)

    gap = abs(rows["ti"]["loss_final"] - rows["ell"]["loss_final"]) \
        / max(rows["ell"]["loss_final"], 1e-9)
    ratio = rows["ti"]["us_per_call"] / max(rows["ell"]["us_per_call"], 1e-9)
    rows["ti_vs_ell"] = {"step_ratio": ratio, "loss_rel_gap": gap,
                         "steps": steps, "gate": not fast}
    rows["ti"]["default_path"] = True   # the store-free production estimator
    print(f"backends/ti_vs_ell,0,step_ratio={ratio:.2f};"
          f"loss_gap={gap:.1%}", flush=True)
    assert rows["ti"]["store_read_bytes_per_step"] == 0
    assert rows["ti"]["store_write_bytes_per_step"] == 0
    return rows


def main() -> None:
    import jax
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    OUT.mkdir(parents=True, exist_ok=True)
    rows = bench_backends(fast=args.fast)
    artifact = {"name": "backends", "backend": jax.default_backend(),
                "agg_backend": "all", "rows": rows}
    path = OUT / "BENCH_backends.json"
    path.write_text(json.dumps(artifact, indent=2, sort_keys=True))
    print(f"# wrote {path.relative_to(ROOT)}")


if __name__ == "__main__":
    main()
