"""Training-supervisor overhead benchmark (DESIGN.md §10) ->
BENCH_supervisor.json.

Rows:

* ``step_unguarded``   — median trainer step with all health checks off
                         (the pre-supervisor hot path);
* ``step_guarded``     — same trainer config with ``HealthConfig()``
                         (NaN/Inf + spike checks every step, staleness
                         counter tick, periodic store sweep).
                         ``ratio_vs_unguarded`` is the number
                         ``scripts/check.sh`` gates at <= 1.10x: the guard
                         must stay noise-level because its inputs (host
                         loss/grad-norm floats) are syncs the step already
                         pays for its history record;
* ``ckpt_sync_save`` / ``ckpt_async_save`` — wall time the *training
                         thread* spends in one checkpoint save: the
                         synchronous path pays serialization + fsync-ish
                         file writes inline, the background path only the
                         ``jax.device_get`` snapshot and thread handoff
                         (``async_speedup`` = sync / async).

Run: ``PYTHONPATH=src python -m benchmarks.bench_supervisor [--fast]`` or
``python -m benchmarks.run --only supervisor``.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

ROOT = Path(__file__).resolve().parents[1]
OUT = ROOT / "experiments" / "bench"

CFG = dict(preset="ppi-cpu", hidden=64, layers=2, parts=16, c=2, lr=0.3)


def _median_step_us(fn, steps: int) -> float:
    times = []
    for _ in range(steps):
        t0 = time.time()
        fn()
        times.append(time.time() - t0)
    return float(np.median(times)) * 1e6


def _make_trainer(tmp, g, parts, **kw):
    from repro.core import LMC
    from repro.graph import ClusterSampler
    from repro.models import make_gnn
    from repro.optim import sgd
    from repro.train import GNNTrainer
    gnn = make_gnn("gcn", g.feature_dim, CFG["hidden"], g.num_classes,
                   CFG["layers"])
    s = ClusterSampler(g, CFG["parts"], CFG["c"], parts=parts, seed=1)
    return GNNTrainer(gnn, LMC, g, s, sgd(lr=CFG["lr"]), seed=0,
                      ckpt_dir=tmp, ckpt_every=10 ** 9, **kw)


def bench_supervisor(fast: bool = False) -> dict:
    """Guarded-vs-unguarded step medians + sync-vs-async checkpoint cost."""
    import tempfile

    from repro.graph import make_sbm_dataset, partition_graph
    from repro.train import HealthConfig

    steps = 30 if fast else 60
    warmup = 5
    g = make_sbm_dataset(CFG["preset"], seed=3)
    parts = partition_graph(g, CFG["parts"], seed=0)
    rows = {}

    with tempfile.TemporaryDirectory() as tmp:
        tr0 = _make_trainer(tmp + "/unguarded", g, parts)
        tr0.run(warmup)
        us_plain = _median_step_us(lambda: tr0.run(1), steps)
        tr0.close()

        tr1 = _make_trainer(tmp + "/guarded", g, parts,
                            health=HealthConfig())
        tr1.run(warmup)
        us_guard = _median_step_us(lambda: tr1.run(1), steps)
        assert not any(h.get("event") for h in tr1.history), \
            "health guard fired on a healthy run"

        ratio = us_guard / us_plain
        rows["step_unguarded"] = {"us_per_call": us_plain}
        rows["step_guarded"] = {"us_per_call": us_guard,
                                "ratio_vs_unguarded": ratio,
                                "default_path": True}
        print(f"supervisor/step_unguarded,{us_plain:.0f},", flush=True)
        print(f"supervisor/step_guarded,{us_guard:.0f},"
              f"ratio_vs_unguarded={ratio:.3f}", flush=True)
        if ratio > 1.10:
            # artifacts must still be written; check.sh enforces the gate
            print(f"# WARNING: guarded step {ratio:.2f}x unguarded "
                  f"(bound 1.10x)", flush=True)

        # checkpoint save cost as seen by the training thread
        iters = 3 if fast else 6
        def save_us(background: bool) -> float:
            best = float("inf")
            for _ in range(iters):
                tr1.ckpt.wait()
                tr1.async_ckpt = background
                t0 = time.time()
                tr1.save()
                best = min(best, time.time() - t0)
            tr1.ckpt.wait()
            return best * 1e6

        us_sync = save_us(False)
        us_async = save_us(True)
        rows["ckpt_sync_save"] = {"us_per_call": us_sync}
        rows["ckpt_async_save"] = {"us_per_call": us_async,
                                   "async_speedup": us_sync / us_async}
        print(f"supervisor/ckpt_sync_save,{us_sync:.0f},", flush=True)
        print(f"supervisor/ckpt_async_save,{us_async:.0f},"
              f"async_speedup={us_sync / us_async:.2f}x", flush=True)
        tr1.close()
    return rows


def main() -> None:
    """Standalone entry point mirroring ``benchmarks.run``'s artifact shape."""
    import jax

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fast", action="store_true",
                    help="fewer timing steps")
    args = ap.parse_args()
    OUT.mkdir(parents=True, exist_ok=True)
    print("name,us_per_call,derived")
    rows = bench_supervisor(fast=args.fast)
    artifact = {"name": "supervisor", "backend": jax.default_backend(),
                "agg_backend": "segment", "rows": rows}
    path = OUT / "BENCH_supervisor.json"
    path.write_text(json.dumps(artifact, indent=2, sort_keys=True))
    print(f"# wrote {path.relative_to(ROOT)}", flush=True)


if __name__ == "__main__":
    main()
