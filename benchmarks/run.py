"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows and writes one machine-readable
``experiments/bench/BENCH_<name>.json`` artifact per benchmark ({name,
backend, rows: {entry: {us_per_call, ...}}}) so the perf trajectory stays
trackable across PRs. Run:
    PYTHONPATH=src python -m benchmarks.run [--only NAME] [--fast]
                                           [--backend {segment,ell}]

Paper mapping (DESIGN.md §6):
  bench_grad_error            -> Fig 3   (relative mini-batch gradient error)
  bench_convergence_speed     -> Tbl 2 / Fig 2 (steps & time to target acc)
  bench_batch_size_robustness -> Tbl 3   (accuracy vs clusters per batch)
  bench_ablation_compensation -> Fig 4 / Tbl 8-9 (C_f / C_b / β)
  bench_time_per_epoch        -> App E.2 (per-epoch wall time by method)
  bench_message_retention     -> Tbl 7   (% adjacency retained fwd/bwd)
  bench_spider                -> App F   (variance-reduced estimator)
  bench_spmm_kernel           -> kernel hot-spot micro-benchmark
  bench_compensate            -> Eq. 9/12 fused gather+lerp micro-benchmark
                                 (streamed vs resident store gather)
  bench_pipeline              -> async sampling pipeline + minibatch
                                 recycling (DESIGN.md §9): sync-vs-prefetch
                                 step times, overlap fraction, ρ=4 parity
  bench_supervisor            -> training-supervisor overhead (DESIGN.md
                                 §10): guarded-vs-unguarded step medians,
                                 sync-vs-async checkpoint save cost
  bench_backends              -> segment vs ell vs ti head-to-head
                                 (DESIGN.md §11): convergence at fixed step
                                 counts + per-step historical-store traffic;
                                 the ti step must stay <= 1.0x the ell step
  bench_serve                 -> serving tier (DESIGN.md §12): client p50/
                                 p99 + throughput across QPS x fault-rate,
                                 degraded-rung parity, drain accounting
"""
from __future__ import annotations

import argparse
import inspect
import json
import time
from pathlib import Path

import numpy as np

ROOT = Path(__file__).resolve().parents[1]
OUT = ROOT / "experiments" / "bench"


def _timer(fn, iters=3):
    """Best-of-iters per-call time in us (min is the noise-robust estimator
    for microbenchmarks — the perf tripwire in scripts/check.sh compares
    these numbers across runs, so jitter must not read as regression)."""
    fn()  # warmup/compile
    best = float("inf")
    for _ in range(iters):
        t0 = time.time()
        fn()
        best = min(best, time.time() - t0)
    return best * 1e6  # us


def _setup(preset="ppi-cpu", hidden=64, layers=3, parts=16, seed=0):
    import jax
    from repro.core import from_graph
    from repro.graph import make_sbm_dataset, partition_graph
    from repro.models import make_gnn
    g = make_sbm_dataset(preset, seed=3)
    data = from_graph(g)
    gnn = make_gnn("gcn", g.feature_dim, hidden, g.num_classes, layers)
    params = gnn.init_params(jax.random.key(seed))
    pts = partition_graph(g, parts, seed=0)
    return g, data, gnn, params, pts


# ------------------------------------------------------------------- Fig 3
def bench_grad_error(fast=False):
    import jax
    import jax.numpy as jnp
    from repro.core import (METHODS, backward_sgd_grads, exact_layer_values,
                            full_grads, init_history, make_train_step,
                            to_device_batch)
    from repro.graph import ClusterSampler
    g, data, gnn, params, parts = _setup()
    hs, vs = exact_layer_values(gnn, params, data)
    _, gfull = full_grads(gnn, params, data)

    def rel(ga, gb):
        f1, f2 = jax.tree.leaves(ga), jax.tree.leaves(gb)
        num = sum(float(jnp.sum((a - b) ** 2)) for a, b in zip(f1, f2))
        den = sum(float(jnp.sum(jnp.asarray(b) ** 2)) for b in f2)
        return (num / max(den, 1e-12)) ** 0.5

    rows = {}
    for name in ("lmc", "gas", "cluster", "cf_only", "cb_only"):
        m = METHODS[name]
        s = ClusterSampler(g, 16, 2, parts=parts, seed=1,
                           include_halo=m.include_halo,
                           edge_weight_mode=m.edge_weight_mode,
                           stochastic=False)
        step = jax.jit(make_train_step(gnn, m, g.num_nodes))
        store = init_history(gnn.num_layers, g.num_nodes, gnn.hidden_dim)
        for _ in range(2 if fast else 4):
            for sg in s.epoch():
                _, _, store, _ = step(params, store, to_device_batch(sg),
                                      data.x, data.self_w)
        bias, err = [], []
        t0 = time.time()
        n = 0
        for sg in s.epoch():
            _, gm, store, _ = step(params, store, to_device_batch(sg),
                                   data.x, data.self_w)
            nodes = jnp.asarray(sg.batch_gids[sg.batch_mask > 0])
            gsgd = backward_sgd_grads(gnn, params, data, hs, vs, nodes,
                                      scale=8.0)
            bias.append(rel(gm["layers"], gsgd))
            err.append(rel(gm, gfull))
            n += 1
        us = (time.time() - t0) / n * 1e6
        rows[name] = {"bias": float(np.mean(bias)),
                      "full_err": float(np.mean(err))}
        print(f"grad_error/{name},{us:.0f},bias={np.mean(bias):.4f};"
              f"err_vs_full={np.mean(err):.4f}", flush=True)
    assert rows["lmc"]["bias"] < rows["gas"]["bias"] < rows["cluster"]["bias"]
    return rows


# ----------------------------------------------------------- Tbl 2 / Fig 2
def bench_convergence_speed(fast=False):
    from repro.core import METHODS
    from repro.graph import ClusterSampler
    from repro.optim import sgd
    from repro.train import GNNTrainer
    g, data, gnn, params, parts = _setup(hidden=64, layers=2)
    target = 0.60
    steps_budget = 150 if fast else 400
    rows = {}
    for name in ("lmc", "gas", "cluster"):
        m = METHODS[name]
        steps, times = [], []
        for seed in range(1 if fast else 3):
            s = ClusterSampler(g, 16, 2, parts=parts, seed=seed,
                               include_halo=m.include_halo,
                               edge_weight_mode=m.edge_weight_mode)
            tr = GNNTrainer(gnn, m, g, s, sgd(lr=0.3), seed=seed)
            t0 = time.time()
            steps_to_target = steps_budget
            for _ in range(steps_budget // 25):
                tr.run(25)
                if float(tr.eval("val")) >= target:
                    steps_to_target = tr.step_num
                    break
            steps.append(steps_to_target)
            times.append(time.time() - t0)
        rows[name] = float(np.mean(steps))
        print(f"convergence/{name},{np.mean(times)*1e6:.0f},"
              f"steps_to_{target}acc={np.mean(steps):.0f}", flush=True)
    return rows


# ------------------------------------------------------------------- Tbl 3
def bench_batch_size_robustness(fast=False):
    from repro.core import GAS, LMC
    from repro.graph import ClusterSampler
    from repro.optim import sgd
    from repro.train import GNNTrainer
    g, data, gnn, params, parts = _setup(hidden=64, layers=2)
    rows = {}
    for c in ([1, 4] if fast else [1, 2, 4]):
        for m in (LMC, GAS):
            s = ClusterSampler(g, 16, c, parts=parts, seed=0,
                               include_halo=m.include_halo,
                               edge_weight_mode=m.edge_weight_mode)
            tr = GNNTrainer(gnn, m, g, s, sgd(lr=0.3), seed=0)
            t0 = time.time()
            tr.run(100 if fast else 200)
            acc = float(tr.eval("test"))
            rows[f"{m.name}_c{c}"] = acc
            print(f"batch_robustness/{m.name}_c{c},"
                  f"{(time.time()-t0)*1e6:.0f},test_acc={acc:.4f}", flush=True)
    return rows


# ----------------------------------------------------------- Fig 4 / Tbl 8
def bench_ablation_compensation(fast=False):
    from repro.core import METHODS
    from repro.graph import ClusterSampler
    from repro.optim import sgd
    from repro.train import GNNTrainer
    g, data, gnn, params, parts = _setup(hidden=64, layers=2)
    rows = {}
    for name in ("lmc", "cf_only", "cb_only", "gas"):
        m = METHODS[name]
        s = ClusterSampler(g, 16, 1, parts=parts, seed=0,  # small batch
                           include_halo=m.include_halo,
                           edge_weight_mode=m.edge_weight_mode)
        tr = GNNTrainer(gnn, m, g, s, sgd(lr=0.3), seed=0)
        t0 = time.time()
        tr.run(100 if fast else 250)
        acc = float(tr.eval("val"))
        rows[name] = acc
        print(f"ablation/{name},{(time.time()-t0)*1e6:.0f},"
              f"val_acc={acc:.4f}", flush=True)
    return rows


# --------------------------------------------------------------- App E.2
def bench_time_per_epoch(fast=False, backend="segment"):
    import jax
    from repro.core import (METHODS, init_history, make_train_step,
                            to_device_batch)
    from repro.graph import ClusterSampler
    g, data, gnn, params, parts = _setup()
    rows = {}
    for name in ("lmc", "gas", "cluster"):
        m = METHODS[name]
        s = ClusterSampler(g, 16, 2, parts=parts, seed=0,
                           include_halo=m.include_halo,
                           edge_weight_mode=m.edge_weight_mode)
        step = jax.jit(make_train_step(gnn, m, g.num_nodes, backend=backend))
        store = init_history(gnn.num_layers, g.num_nodes, gnn.hidden_dim)
        batches = [to_device_batch(sg, backend=backend) for sg in s.epoch()]

        def epoch():
            nonlocal store
            for b in batches:
                _, _, store, _ = step(params, store, b, data.x, data.self_w)
            jax.block_until_ready(store.h)

        us = _timer(epoch, iters=2 if fast else 4)
        rows[f"{name}_{backend}"] = {"us_per_call": us, "backend": backend}
        print(f"time_per_epoch/{name}_{backend},{us:.0f},epoch_s={us/1e6:.3f}",
              flush=True)
    return rows


# ------------------------------------------------------------------- Tbl 7
def bench_message_retention(fast=False):
    """% of whole-graph messages retained in fwd/bwd per method (Tbl 7)."""
    from repro.core import METHODS
    from repro.graph import ClusterSampler
    g, data, gnn, params, parts = _setup()
    total = g.num_edges
    rows = {}
    for name in ("lmc", "gas", "cluster"):
        m = METHODS[name]
        s = ClusterSampler(g, 16, 2, parts=parts, seed=0,
                           include_halo=m.include_halo,
                           edge_weight_mode=m.edge_weight_mode,
                           stochastic=False)
        # paper Tbl 7: fraction of Ã entries participating at least once per
        # epoch; GAS's backward only propagates adjoints along batch-internal
        # edges, LMC compensates the rest (100% like full-batch GD)
        fwd_edges, bwd_edges = set(), set()
        t0 = time.time()
        for sg in s.epoch():
            gids = np.concatenate([sg.batch_gids, sg.halo_gids])
            ne = sg.n_edges_real
            su = gids[sg.edge_src[:ne]].astype(np.int64)
            dv = gids[sg.edge_dst[:ne]].astype(np.int64)
            code = su * g.num_nodes + dv
            fwd_edges.update(code.tolist())
            if name == "lmc":
                bwd_edges.update(code.tolist())
            else:
                nb = sg.batch_gids.shape[0]
                intra = (sg.edge_src[:ne] < nb) & (sg.edge_dst[:ne] < nb)
                bwd_edges.update(code[intra].tolist())
        us = (time.time() - t0) * 1e6
        rows[name] = {"us_per_call": us, "fwd": len(fwd_edges) / total,
                      "bwd": len(bwd_edges) / total}
        print(f"message_retention/{name},{us:.0f},"
              f"fwd={len(fwd_edges)/total:.2%};bwd={len(bwd_edges)/total:.2%}",
              flush=True)
    return rows


# --------------------------------------------------------------------- App F
def bench_spider(fast=False):
    """LMC-SPIDER: the anchored running estimate has lower error than the
    plain per-batch estimate at equal small-batch cost (App. F)."""
    import jax
    import jax.numpy as jnp
    from repro.core import (LMC, full_grads, init_history, make_train_step,
                            to_device_batch)
    from repro.graph import ClusterSampler
    from repro.optim import make_spider_controller
    g, data, gnn, params, parts = _setup(hidden=32, layers=2)
    s = ClusterSampler(g, 16, 2, parts=parts, seed=0)
    step = jax.jit(make_train_step(gnn, LMC, g.num_nodes))
    store = init_history(gnn.num_layers, g.num_nodes, gnn.hidden_dim)
    for _ in range(2):
        for sg in s.epoch():
            _, _, store, _ = step(params, store, to_device_batch(sg),
                                  data.x, data.self_w)
    _, gfull = full_grads(gnn, params, data)

    def rel(ga):
        f1, f2 = jax.tree.leaves(ga), jax.tree.leaves(gfull)
        num = sum(float(jnp.sum((a - b) ** 2)) for a, b in zip(f1, f2))
        den = sum(float(jnp.sum(jnp.asarray(b) ** 2)) for b in f2)
        return (num / max(den, 1e-12)) ** 0.5

    init, _, anchor, refine = make_spider_controller(q=4)
    sa = ClusterSampler(g, 16, 8, parts=parts, seed=3)   # large anchor batch
    t0 = time.time()
    _, g_anchor, store, _ = step(params, store, to_device_batch(sa.sample()),
                                 data.x, data.self_w)
    st = anchor(init(params), params, g_anchor)
    plain_errs, spider_errs = [], []
    for _ in range(4 if fast else 8):
        sg = s.sample()
        _, g_small, store, _ = step(params, store, to_device_batch(sg),
                                    data.x, data.self_w)
        plain_errs.append(rel(g_small))
        # fixed params: the SPIDER difference term cancels exactly, so the
        # estimate stays anchored at the large-batch gradient
        st = refine(st, params, g_small, g_small)
        spider_errs.append(rel(st.g_est))
    us = (time.time() - t0) * 1e6
    print(f"spider,{us:.0f},plain_err={np.mean(plain_errs):.4f};"
          f"spider_err={np.mean(spider_errs):.4f}", flush=True)
    assert np.mean(spider_errs) < np.mean(plain_errs)
    return {"spider": {"us_per_call": us,
                       "plain_err": float(np.mean(plain_errs)),
                       "spider_err": float(np.mean(spider_errs))}}


# ----------------------------------------------------------------- kernels
def bench_spmm_kernel(fast=False):
    import jax
    import jax.numpy as jnp
    from repro.kernels import build_ell, bucketed_spmm, default_interpret
    from repro.kernels.ops import _build_ell_loop
    from repro.kernels.ref import degree_bucket_spmm_ref
    g, data, gnn, params, parts = _setup()
    row = np.repeat(np.arange(g.num_nodes), np.diff(g.indptr))
    ws = g.gcn_edge_weights(g.indices.astype(np.int64), row)
    ell = build_ell(g.indptr, g.indices, ws)
    h = jnp.asarray(np.random.default_rng(0).normal(
        size=(g.num_nodes, 128)).astype(np.float32))
    ptr, ind, wj = (jnp.asarray(g.indptr), jnp.asarray(g.indices),
                    jnp.asarray(ws))
    ref = jax.jit(lambda h_: degree_bucket_spmm_ref(ptr, ind, wj, h_))
    # identical protocol for all paths: _timer warms up (compile/trace) then
    # takes best-of-iters over the same number of steady-state iterations
    iters = 3 if fast else 5
    us_ref = _timer(lambda: jax.block_until_ready(ref(h)), iters=iters)
    # streamed (HBM→VMEM DMA double buffer, the default) vs resident
    # ((M, block_d) VMEM feature block, the pre-streaming path)
    us_str = _timer(lambda: jax.block_until_ready(
        bucketed_spmm(ell, h, stream=True)), iters=iters)
    us_res = _timer(lambda: jax.block_until_ready(
        bucketed_spmm(ell, h, stream=False)), iters=iters)
    nnz = g.num_edges
    gflops = lambda us: 2 * nnz * 128 / us / 1e3
    mode = "interpret" if default_interpret() else "compiled"
    rows = {
        "jnp_segment_sum": {"us_per_call": us_ref, "gflops": gflops(us_ref)},
        f"pallas_{mode}_streamed": {"us_per_call": us_str,
                                    "gflops": gflops(us_str),
                                    "default_path": True},
        f"pallas_{mode}_resident": {"us_per_call": us_res,
                                    "gflops": gflops(us_res)},
    }
    print(f"spmm/jnp_segment_sum,{us_ref:.0f},gflops={gflops(us_ref):.2f}",
          flush=True)
    note = (";note=interpret-mode;TPU-target-not-CPU-representative"
            if mode == "interpret" else "")
    print(f"spmm/pallas_{mode}_streamed,{us_str:.0f},"
          f"gflops={gflops(us_str):.2f}{note}", flush=True)
    print(f"spmm/pallas_{mode}_resident,{us_res:.0f},"
          f"gflops={gflops(us_res):.2f}{note}", flush=True)

    # ELL preprocessing: vectorized bulk-numpy builder vs the original
    # per-node Python loop, on a 50k-node synthetic CSR graph
    rng = np.random.default_rng(1)
    n50, avg_deg = 50_000, 10
    e50 = n50 * avg_deg
    dst = np.sort(rng.integers(0, n50, e50))
    indptr50 = np.zeros(n50 + 1, np.int64)
    indptr50[1:] = np.cumsum(np.bincount(dst, minlength=n50))
    indices50 = rng.integers(0, n50, e50).astype(np.int32)
    ws50 = rng.random(e50).astype(np.float32)
    t0 = time.time()
    _build_ell_loop(indptr50, indices50, ws50)
    us_loop = (time.time() - t0) * 1e6
    us_vec = _timer(lambda: build_ell(indptr50, indices50, ws50,
                                      with_transpose=False), iters=iters)
    speedup = us_loop / us_vec
    rows["build_ell_loop_50k"] = {"us_per_call": us_loop}
    rows["build_ell_vectorized_50k"] = {"us_per_call": us_vec,
                                        "speedup_vs_loop": speedup}
    print(f"spmm/build_ell_loop_50k,{us_loop:.0f},n=50000", flush=True)
    print(f"spmm/build_ell_vectorized_50k,{us_vec:.0f},"
          f"speedup_vs_loop={speedup:.1f}x", flush=True)
    if speedup < 10.0:
        # don't abort the harness (artifacts must still be written for the
        # remaining benches); scripts/check.sh enforces the tripwire
        print(f"# WARNING: vectorized build_ell only {speedup:.1f}x faster "
              f"than the loop (expected >= 10x)", flush=True)
    return rows


def bench_compensate(fast=False):
    """Fused LMC compensate (Eq. 9/12) micro-benchmark: jnp oracle vs the
    Pallas kernel, streamed (HBM→VMEM DMA, the default) vs resident store
    block — plus a streamed run at 4x the old ~24k-row cap, which the
    resident path cannot compile at all. Same protocol as bench_spmm_kernel
    (warmup + equal steady-state iters); derived metric is effective GB/s
    over the gather+lerp traffic (store row reads + fresh reads + writes)."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import default_interpret, lmc_compensate
    from repro.kernels.ref import lmc_compensate_ref

    rng = np.random.default_rng(0)
    n, d = 4096, 128                       # halo rows x hidden (train-scale)
    iters = 3 if fast else 5
    mode = "interpret" if default_interpret() else "compiled"
    rows = {}

    def one(entry, m, **kw):
        store = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
        gids = jnp.asarray(rng.integers(0, m, n).astype(np.int32))
        beta = jnp.asarray(rng.random(n).astype(np.float32))
        mask = jnp.asarray((rng.random(n) > 0.2).astype(np.float32))
        fresh = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
        if kw:
            fn = jax.jit(lambda *a: lmc_compensate(*a, **kw))
        else:
            fn = jax.jit(lambda *a: lmc_compensate_ref(*a))
        us = _timer(lambda: jax.block_until_ready(
            fn(store, gids, beta, fresh, mask)), iters=iters)
        gbps = 3 * n * d * 4 / us / 1e3    # store-gather + fresh + out bytes
        rows[entry] = {"us_per_call": us, "gbps": gbps, "store_rows": m}
        print(f"compensate/{entry},{us:.0f},gbps={gbps:.2f};m={m}", flush=True)

    m_small = 16384                        # fits the old resident-block cap
    one("jnp_oracle", m_small)
    one(f"pallas_{mode}_streamed", m_small, stream=True)
    one(f"pallas_{mode}_resident", m_small, stream=False)
    # full-graph-scale store: only the streamed path can compile this
    one(f"pallas_{mode}_streamed_4xcap", 4 * 24576, stream=True)
    rows[f"pallas_{mode}_streamed"]["default_path"] = True
    return rows


from benchmarks.bench_backends import bench_backends  # noqa: E402
from benchmarks.bench_pipeline import bench_pipeline  # noqa: E402
from benchmarks.bench_serve import bench_serve  # noqa: E402
from benchmarks.bench_supervisor import bench_supervisor  # noqa: E402

BENCHES = {
    "grad_error": bench_grad_error,
    "convergence_speed": bench_convergence_speed,
    "batch_size_robustness": bench_batch_size_robustness,
    "ablation_compensation": bench_ablation_compensation,
    "time_per_epoch": bench_time_per_epoch,
    "message_retention": bench_message_retention,
    "spider": bench_spider,
    "spmm_kernel": bench_spmm_kernel,
    "compensate": bench_compensate,
    "pipeline": bench_pipeline,
    "supervisor": bench_supervisor,
    "backends": bench_backends,
    "serve": bench_serve,
}


def main() -> None:
    import jax

    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--backend", default="segment",
                    choices=["segment", "ell", "ti"],
                    help="aggregation hot path for train-step benches")
    args = ap.parse_args()
    OUT.mkdir(parents=True, exist_ok=True)
    names = [args.only] if args.only else list(BENCHES)
    print("name,us_per_call,derived")
    for n in names:
        fn = BENCHES[n]
        kw = {"fast": args.fast}
        if "backend" in inspect.signature(fn).parameters:
            kw["backend"] = args.backend
        rows = fn(**kw)
        artifact = {"name": n, "backend": jax.default_backend(),
                    "agg_backend": kw.get("backend", "segment"),
                    "rows": rows or {}}
        # the kernel bench is the cross-PR perf tripwire: short stable name
        path = OUT / {"spmm_kernel": "BENCH_spmm.json"}.get(n,
                                                            f"BENCH_{n}.json")
        path.write_text(json.dumps(artifact, indent=2, sort_keys=True))
        print(f"# wrote {path.relative_to(ROOT)}", flush=True)


if __name__ == "__main__":
    main()
