"""Serving-tier benchmark (DESIGN.md §12) -> BENCH_serve.json.

Measures the GNNServer end to end — admission queue, bucket batcher,
compiled infer traces, degradation policy — the way a client sees it:

* ``qps<q>_clean`` / ``qps<q>_faulty`` — paced open-loop load at three QPS
  levels, 0% and injected fault rates (slow batch + poisoned store rows).
  ``us_per_call`` is the p50 client-observed latency; p99 and achieved
  throughput ride along. ``scripts/check.sh`` gates the clean p99 at
  <= 1.3x the committed baseline at the fixed middle QPS level.
* ``parity_ti`` — the degraded store-free rung vs the exact rung on the
  same trained params: top-1 agreement and the val-accuracy gap. The gap
  is the quality floor of every degraded answer the robustness ladder
  serves; check.sh gates it at <= 0.05.
* ``drain`` — graceful-shutdown accounting: every admitted request must be
  resolved (``dropped`` gated at 0).

Run: ``PYTHONPATH=src python -m benchmarks.bench_serve [--fast]`` or
``python -m benchmarks.run --only serve``.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

ROOT = Path(__file__).resolve().parents[1]
OUT = ROOT / "experiments" / "bench"

CFG = dict(preset="ppi-cpu", hidden=64, layers=3, parts=16, c=2, lr=0.3)
QPS_LEVELS = (50, 200, 800)
GATED_QPS = 200          # the level check.sh compares across commits


def _trained_setup(steps: int):
    import jax  # noqa: F401  (device init before timing)
    from repro.core import LMC
    from repro.graph import ClusterSampler, make_sbm_dataset, partition_graph
    from repro.models import make_gnn
    from repro.optim import sgd
    from repro.train import GNNTrainer
    g = make_sbm_dataset(CFG["preset"], seed=3)
    gnn = make_gnn("gcn", g.feature_dim, CFG["hidden"], g.num_classes,
                   CFG["layers"])
    parts = partition_graph(g, CFG["parts"], seed=0)
    sampler = ClusterSampler(g, CFG["parts"], CFG["c"], parts=parts, seed=1)
    tr = GNNTrainer(gnn, LMC, g, sampler, sgd(lr=CFG["lr"]), seed=0)
    tr.run(steps)
    return g, gnn, tr


def _server(g, gnn, tr, plan=None, **cfg_kw):
    from repro.serve import GNNServer, ServeConfig
    cfg = ServeConfig(default_deadline_s=10.0, warmup=True, **cfg_kw)
    return GNNServer(gnn, g, tr.params, config=cfg, fault_plan=plan,
                     data=tr.data)


def _load(srv, g, qps: float, n_requests: int, seed: int,
          plan=None) -> dict:
    """Open-loop paced load; returns client-observed latency/throughput."""
    rng = np.random.default_rng(seed)
    period = 1.0 / qps
    futs = []
    t0 = time.time()
    for i in range(n_requests):
        k = int(rng.integers(1, 9))
        nodes = rng.choice(g.num_nodes, size=k, replace=False)
        futs.append(srv.submit(nodes, request_id=f"q{i}"))
        time.sleep(max(0.0, t0 + (i + 1) * period - time.time()))
    rs = [f.result(timeout=120.0) for f in futs]
    wall = time.time() - t0
    lat = np.array([r.latency_s for r in rs if r.ok])
    from collections import Counter
    statuses = dict(sorted(Counter(r.status for r in rs).items()))
    return {
        "us_per_call": float(np.percentile(lat, 50)) * 1e6,
        "p99_us": float(np.percentile(lat, 99)) * 1e6,
        "throughput_rps": len(rs) / wall,
        "answered": int(lat.size),
        "statuses": statuses,
    }


def bench_serve(fast: bool = False) -> dict:
    """p50/p99/throughput across QPS x fault-rate, ti parity, drain audit."""
    from repro.core.exact import accuracy
    from repro.train.health import FaultPlan

    train_steps = 60 if fast else 120
    n_requests = 48 if fast else 96
    g, gnn, tr = _trained_setup(train_steps)
    rows = {}

    srv = _server(g, gnn, tr)
    try:
        for qps in QPS_LEVELS:
            row = _load(srv, g, qps, n_requests, seed=qps)
            if qps == GATED_QPS:
                row["default_path"] = True   # the cross-PR latency tripwire
            rows[f"qps{qps}_clean"] = row
            print(f"serve/qps{qps}_clean,{row['us_per_call']:.0f},"
                  f"p99_us={row['p99_us']:.0f} "
                  f"rps={row['throughput_rps']:.1f}", flush=True)
    finally:
        srv.close(drain=False)

    # nonzero fault rate: a stalled batch + two poisoned-row strikes per run
    for qps in QPS_LEVELS:
        # low batch seqs: high-QPS runs coalesce many requests per batch,
        # so late seqs would never be reached
        plan = FaultPlan(serve_slow_at=(2,), serve_slow_s=0.05,
                         serve_poison_at=(3, 5))
        srv = _server(g, gnn, tr, plan=plan)
        try:
            row = _load(srv, g, qps, n_requests, seed=qps, plan=plan)
            rows[f"qps{qps}_faulty"] = row
            print(f"serve/qps{qps}_faulty,{row['us_per_call']:.0f},"
                  f"p99_us={row['p99_us']:.0f} "
                  f"statuses={row['statuses']}", flush=True)
        finally:
            srv.close(drain=False)

    # degraded-rung parity: ti answers vs exact answers on trained params
    srv = _server(g, gnn, tr)
    srv_ti = _server(g, gnn, tr, force_mode="ti", verify_rows=False,
                     repair=False)
    try:
        rng = np.random.default_rng(0)
        nodes = rng.permutation(g.num_nodes)[:512 if fast else 1024]
        agree = both = 0
        ti_pred = np.zeros(g.num_nodes, dtype=np.int64)
        exact_pred = np.zeros(g.num_nodes, dtype=np.int64)
        for chunk in np.array_split(nodes, -(-nodes.size // 128)):
            re_ = srv.infer(chunk)
            rt = srv_ti.infer(chunk)
            assert re_.status == "ok" and rt.ok, (re_.status, rt.status)
            exact_pred[chunk] = re_.classes
            ti_pred[chunk] = rt.classes
            agree += int((re_.classes == rt.classes).sum())
            both += chunk.size
        val = np.asarray(g.val_mask) & np.isin(np.arange(g.num_nodes), nodes)
        y = np.asarray(g.y if hasattr(g, "y") else g.labels)
        acc_exact = float((exact_pred[val] == y[val]).mean())
        acc_ti = float((ti_pred[val] == y[val]).mean())
        # full-graph reference keeps the exact rung honest
        acc_full = float(accuracy(gnn, tr.params, tr.data,
                                  np.asarray(g.val_mask, np.float32)))
        rows["parity_ti"] = {
            "us_per_call": 0.0,
            "top1_agreement": agree / both,
            "val_acc_exact": acc_exact,
            "val_acc_ti": acc_ti,
            "val_acc_gap": abs(acc_exact - acc_ti),
            "val_acc_full_forward": acc_full,
        }
        print(f"serve/parity_ti,0,agreement={agree / both:.3f} "
              f"acc_gap={abs(acc_exact - acc_ti):.3f}", flush=True)
    finally:
        srv.close(drain=False)
        srv_ti.close(drain=False)

    # drain audit: every admitted request resolves; zero dropped in flight
    srv = _server(g, gnn, tr)
    rng = np.random.default_rng(7)
    futs = [srv.submit(rng.choice(g.num_nodes, size=4, replace=False))
            for _ in range(32)]
    drained = srv.drain(timeout=120.0)
    rs = [f.result(timeout=1.0) for f in futs]
    resolved_ok = sum(1 for r in rs if r.ok)
    dropped = sum(1 for r in rs if not r.ok)
    rows["drain"] = {"us_per_call": 0.0, "submitted": len(futs),
                     "resolved_ok": resolved_ok, "dropped": dropped,
                     "clean_exit": bool(drained)}
    print(f"serve/drain,0,submitted={len(futs)} ok={resolved_ok} "
          f"dropped={dropped}", flush=True)
    return rows


def main() -> None:
    """Standalone entry point mirroring ``benchmarks.run``'s artifact shape."""
    import jax

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fast", action="store_true",
                    help="fewer requests and training steps")
    args = ap.parse_args()
    OUT.mkdir(parents=True, exist_ok=True)
    print("name,us_per_call,derived")
    rows = bench_serve(fast=args.fast)
    artifact = {"name": "serve", "backend": jax.default_backend(),
                "agg_backend": "segment", "rows": rows}
    path = OUT / "BENCH_serve.json"
    path.write_text(json.dumps(artifact, indent=2, sort_keys=True))
    print(f"# wrote {path.relative_to(ROOT)}", flush=True)


if __name__ == "__main__":
    main()
