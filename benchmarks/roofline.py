import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Roofline extraction for every (arch × shape) cell on the single-pod mesh.

cost_analysis() counts while-loop (lax.scan) bodies ONCE (verified in
DESIGN.md §7.1), so per-layer costs are measured from *unrolled shallow
builds* and extrapolated:

    total(X) = cost(profile_A) + Σ_seg (L_seg - A_seg) · (cost(B_seg) - cost(A))

where profile A has depth 1 per segment and B_seg adds one layer to segment
`seg` only. Unrolled builds also disable attention-KV chunking and MoE
dispatch chunking so no FLOPs hide inside loops; microbatch accumulation
unrolls as a Python loop (exact). memory/collective structure of the real
deployable (scanned) build comes from experiments/dryrun/*.json.

Hardware model (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
50 GB/s/link ICI (single-link-per-collective-step assumption — conservative).

    compute_term   = HLO_FLOPs_per_dev / 197e12
    memory_term    = HLO_bytes_per_dev / 819e9
    collective_term= collective_bytes_per_dev / 50e9

Outputs experiments/roofline/<arch>_<shape>.json and a markdown table.
"""
import argparse
import json
from pathlib import Path

import numpy as np

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

ROOT = Path(__file__).resolve().parents[1]
OUT_DIR = ROOT / "experiments" / "roofline"
DRYRUN_DIR = ROOT / "experiments" / "dryrun"


def _cell_costs(cfg, shape, mesh, profile, collect=True):
    """flops/bytes(/collective bytes) of one unrolled shallow build."""
    import jax
    from repro.launch.steps import build_cell
    from repro.launch.dryrun import collective_bytes, cost_analysis_dict

    lm, step, args, shs = build_cell(cfg, shape, mesh,
                                     depth_profile=profile, unroll=True)
    with mesh:
        lowered = jax.jit(step, in_shardings=shs).lower(*args)
        compiled = lowered.compile(
            compiler_options={"xla_backend_optimization_level": "0"})
    ca = cost_analysis_dict(compiled)
    coll = collective_bytes(compiled.as_text())["total"] if collect else 0.0
    return (float(ca.get("flops", 0.0)),
            float(ca.get("bytes accessed", 0.0)), float(coll))


def _seg_counts(cfg):
    from repro.models.lm import LM
    return {s.name: s.count for s in LM(cfg).segments}


def extrapolate(cfg, shape, mesh):
    counts = _seg_counts(cfg)
    segs = [k for k, v in counts.items() if v > 0]
    base_prof = {k: 1 for k in segs}
    base = _cell_costs(cfg, shape, mesh, base_prof)
    total = np.array(base)
    detail = {"base": base, "marginal": {}}
    for s in segs:
        prof = dict(base_prof)
        prof[s] = 2
        two = _cell_costs(cfg, shape, mesh, prof)
        marg = np.array(two) - np.array(base)
        detail["marginal"][s] = marg.tolist()
        total = total + (counts[s] - 1) * marg
    return total, detail, counts


# ------------------------------------------------- analytic "useful" FLOPs
def model_flops(cfg, shape) -> float:
    """6·N_active·D (+ causal attention term) — the MFU numerator."""
    n_active = active_params(cfg)
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "decode":
        tokens = shape.global_batch  # one new token per sequence
    fwd_only = shape.kind != "train"
    mult = 2.0 if fwd_only else 6.0
    flops = mult * n_active * tokens
    # attention score/value matmuls (causal 1/2 for train/prefill)
    attn_layers = _attn_layer_count(cfg)
    dh_q = cfg.mla.nope_head_dim + cfg.mla.rope_head_dim if cfg.mla else cfg.dh
    dh_v = cfg.mla.v_head_dim if cfg.mla else cfg.dh
    per_tok_ctx = (shape.seq_len / 2.0 if shape.kind != "decode"
                   else shape.seq_len)
    attn = (2.0 if fwd_only else 6.0) * attn_layers * cfg.n_heads \
        * (dh_q + dh_v) * per_tok_ctx * tokens
    if cfg.family in ("ssm",):
        attn = 0.0
    if cfg.family == "hybrid":
        n_attn_blocks = cfg.n_layers // cfg.attn_every
        attn = (2.0 if fwd_only else 6.0) * n_attn_blocks * cfg.n_heads \
            * 2 * cfg.dh * per_tok_ctx * tokens
    if cfg.mtp_depth and shape.kind == "train":
        flops *= 1.0 + cfg.mtp_depth / max(cfg.n_layers, 1)
    return flops + attn


def _attn_layer_count(cfg):
    if cfg.family == "encdec":
        return cfg.enc_layers + 2 * cfg.dec_layers  # self + cross
    if cfg.family == "vlm":
        return cfg.n_layers  # self layers + cross (approx: ctx differs)
    if cfg.family in ("ssm",):
        return 0
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.attn_every
    return cfg.n_layers


def active_params(cfg) -> float:
    n = cfg.param_count()
    if cfg.moe is None:
        return float(n)
    mo = cfg.moe
    d = cfg.d_model
    routed_total = (cfg.n_layers - mo.first_dense_layers) \
        * mo.num_experts * 3 * d * mo.d_expert
    routed_active = (cfg.n_layers - mo.first_dense_layers) \
        * mo.top_k * 3 * d * mo.d_expert
    return float(n - routed_total + routed_active)


def run_cell(arch: str, shape_name: str):
    import jax
    from repro.configs import SHAPES, applicable_shapes, get_config
    from repro.launch.mesh import make_production_mesh

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape_name not in applicable_shapes(cfg):
        return {"arch": arch, "shape": shape_name, "status": "skipped"}
    mesh = make_production_mesh(multi_pod=False)
    n_chips = 256

    (flops, byts, coll), detail, counts = extrapolate(cfg, shape, mesh)
    # per-device: the compiled module is already the per-device program
    t_comp = flops / PEAK_FLOPS
    t_mem = byts / HBM_BW
    t_coll = coll / LINK_BW
    terms = {"compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(cfg, shape) / n_chips
    res = {
        "arch": arch, "shape": shape_name, "mesh": "16x16", "status": "ok",
        "hlo_flops_per_dev": flops, "hlo_bytes_per_dev": byts,
        "collective_bytes_per_dev": coll,
        **{k: round(v, 6) for k, v in terms.items()},
        "bottleneck": bottleneck.replace("_s", ""),
        "model_flops_per_dev": mf,
        "useful_flops_ratio": round(mf / max(flops, 1.0), 3),
        "roofline_fraction": round(t_comp / max(t_comp, t_mem, t_coll), 3),
        "seg_counts": counts,
        "detail": detail,
    }
    # deploy-build memory from the dry-run record
    dr = DRYRUN_DIR / f"{arch}_{shape_name}_16x16.json"
    if dr.exists():
        d = json.loads(dr.read_text())
        if d.get("status") == "ok":
            res["deploy_memory_gb"] = d["memory"]["peak_per_device_gb"]
            res["deploy_collectives"] = d["collectives"]
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    args = ap.parse_args()
    from repro.configs import ARCH_NAMES, SHAPES
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    archs = [args.arch] if args.arch else ARCH_NAMES
    shapes = [args.shape] if args.shape else list(SHAPES)
    rows = []
    for a in archs:
        for s in shapes:
            try:
                r = run_cell(a, s)
            except Exception as e:  # noqa: BLE001
                r = {"arch": a, "shape": s, "status": "error", "error": repr(e)}
                print("FAIL", a, s, repr(e), flush=True)
            (OUT_DIR / f"{a}_{s}.json".replace("/", "_")).write_text(
                json.dumps(r, indent=1, default=float))
            if r.get("status") == "ok":
                print(f"{a:26s} {s:12s} comp {r['compute_s']*1e3:8.2f}ms "
                      f"mem {r['memory_s']*1e3:8.2f}ms "
                      f"coll {r['collective_s']*1e3:8.2f}ms "
                      f"-> {r['bottleneck']:10s} "
                      f"useful {r['useful_flops_ratio']:.2f} "
                      f"roofline {r['roofline_fraction']:.2f}", flush=True)
            rows.append(r)
    print(f"\n{sum(1 for r in rows if r.get('status')=='ok')} ok, "
          f"{sum(1 for r in rows if r.get('status')=='skipped')} skipped, "
          f"{sum(1 for r in rows if r.get('status')=='error')} errors")


if __name__ == "__main__":
    main()
