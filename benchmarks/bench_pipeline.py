"""Async sampling pipeline benchmark (DESIGN.md §9) -> BENCH_pipeline.json.

Measures, on the synthetic benchmark graph:

* ``step_compute``        — pure device step time on a prebuilt batch (the
                            floor every pipelined configuration chases);
* ``sample_build``        — host cost of one fresh batch (schedule draw +
                            ``build_batch`` + ``host_batch`` + device_put);
* ``step_sync``           — synchronous path: compute + sampling paid serially
                            every step (``SubgraphPipeline(depth=0)``);
* ``step_prefetch``       — background pipeline, depth 2 / 2 workers;
* ``step_prefetch_recycle4`` — same plus minibatch recycling ρ=4;
* ``overlap``             — fraction of the per-step host sampling cost the
                            pipeline hides, with and without recycling
                            (``(sync - pipelined) / sample``, clipped to
                            [0, 1]); `scripts/check.sh` gates regressions of
                            the recycled figure and the prefetch-vs-compute
                            ratio (the ≤ 1.15x acceptance bar);
* ``recycle_parity``      — full-graph train loss after equal step counts
                            with ρ=1 vs ρ=4 (epoch schedule). ``gate`` marks
                            full-fidelity runs (>= 1000 steps); fast runs
                            record the numbers but are not held to the ±5%
                            parity bar, since ρ=4 has seen 4x fewer distinct
                            subgraphs at short horizons.

Note: on a single-core container (this CI box: `nproc` == 1) the host
sampling thread and the XLA CPU compute thread time-slice one core, so
``step_prefetch`` cannot beat ``step_sync`` by parallelism — the honest win
there comes from recycling, which removes host work instead of hiding it.
On a multi-core host or a real TPU the prefetch row alone approaches
``step_compute``.

Run: ``PYTHONPATH=src python -m benchmarks.bench_pipeline [--fast]`` or via
``python -m benchmarks.run --only pipeline``.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from pathlib import Path

import numpy as np

ROOT = Path(__file__).resolve().parents[1]
OUT = ROOT / "experiments" / "bench"

# timing config: compute-heavy enough that sampling (~6%) can be fully hidden
TIMING = dict(preset="arxiv-cpu", hidden=128, layers=3, parts=32, c=4)
# parity config: cheap steps so the full-fidelity horizon stays ~1 min
PARITY = dict(preset="ppi-cpu", hidden=64, layers=2, parts=16, c=2,
              lr=0.04, mode="epoch")


def _median_step_us(fn, steps: int) -> float:
    """Median per-call wall time in us over ``steps`` calls (post-warmup)."""
    times = []
    for _ in range(steps):
        t0 = time.time()
        fn()
        times.append(time.time() - t0)
    return float(np.median(times)) * 1e6


def _timing_rows(fast: bool) -> dict:
    import jax
    from repro.core import LMC, from_graph, init_history, make_train_step
    from repro.data import SubgraphPipeline
    from repro.graph import ClusterSampler, make_sbm_dataset, partition_graph
    from repro.models import make_gnn

    cfg = TIMING
    steps = 12 if fast else 24
    g = make_sbm_dataset(cfg["preset"], seed=3)
    data = from_graph(g)
    gnn = make_gnn("gcn", g.feature_dim, cfg["hidden"], g.num_classes,
                   cfg["layers"])
    params = gnn.init_params(jax.random.key(0))
    pts = partition_graph(g, cfg["parts"], seed=0)
    sampler = ClusterSampler(g, cfg["parts"], cfg["c"], parts=pts, seed=1)
    step = jax.jit(make_train_step(gnn, LMC, g.num_nodes))
    store0 = init_history(gnn.num_layers, g.num_nodes, gnn.hidden_dim)

    def one_batch():
        p = SubgraphPipeline(sampler, depth=0, num_steps=1)
        b = next(p)
        p.close()
        return b

    # warmup/compile once; all paths share the jit cache (fixed shapes)
    warm = one_batch()
    state = {"store": store0}
    loss, _, state["store"], _ = step(params, state["store"], warm,
                                      data.x, data.self_w)
    jax.block_until_ready(loss)

    def compute_only():
        loss, _, state["store"], _ = step(params, state["store"], warm,
                                          data.x, data.self_w)
        jax.block_until_ready(loss)

    us_compute = _median_step_us(compute_only, steps)
    us_sample = _median_step_us(one_batch, max(8, steps // 2))

    def pipelined_us(**pipe_kw) -> float:
        state["store"] = store0
        pipe = SubgraphPipeline(sampler, num_steps=steps + 2, **pipe_kw)

        def one_step():
            b = next(pipe)
            loss, _, state["store"], _ = step(params, state["store"], b,
                                              data.x, data.self_w)
            jax.block_until_ready(loss)

        one_step()  # let the queue fill once before timing
        us = _median_step_us(one_step, steps)
        pipe.close()
        return us

    us_sync = pipelined_us(depth=0)
    us_pre = pipelined_us(depth=2, workers=2)
    us_rec = pipelined_us(depth=2, workers=2, recycle=4)

    def hidden(us_row: float) -> float:
        return float(np.clip((us_sync - us_row) / max(us_sample, 1e-9), 0, 1))

    rows = {
        "step_compute": {"us_per_call": us_compute},
        "sample_build": {"us_per_call": us_sample},
        "step_sync": {"us_per_call": us_sync,
                      "ratio_vs_compute": us_sync / us_compute},
        "step_prefetch": {"us_per_call": us_pre,
                          "ratio_vs_compute": us_pre / us_compute,
                          "depth": 2, "workers": 2, "default_path": True},
        "step_prefetch_recycle4": {"us_per_call": us_rec,
                                   "ratio_vs_compute": us_rec / us_compute,
                                   "depth": 2, "workers": 2, "recycle": 4},
        "overlap": {
            "overlap_fraction": hidden(us_pre),
            "overlap_fraction_recycle4": hidden(us_rec),
            "sample_frac_of_step": us_sample / max(us_compute, 1e-9),
            "cpu_count": os.cpu_count(),
        },
    }
    for k in ("step_compute", "step_sync", "step_prefetch",
              "step_prefetch_recycle4"):
        print(f"pipeline/{k},{rows[k]['us_per_call']:.0f},"
              f"ratio_vs_compute="
              f"{rows[k].get('ratio_vs_compute', 1.0):.3f}", flush=True)
    ov = rows["overlap"]
    print(f"pipeline/overlap,{us_sample:.0f},"
          f"prefetch={ov['overlap_fraction']:.2f};"
          f"recycle4={ov['overlap_fraction_recycle4']:.2f};"
          f"cpus={ov['cpu_count']}", flush=True)
    return rows


def _parity_rows(fast: bool) -> dict:
    from repro.core import LMC, from_graph, full_loss
    from repro.graph import ClusterSampler, make_sbm_dataset, partition_graph
    from repro.models import make_gnn
    from repro.optim import sgd
    from repro.train import GNNTrainer

    cfg = PARITY
    steps = 200 if fast else 1000
    g = make_sbm_dataset(cfg["preset"], seed=3)
    data = from_graph(g)
    pts = partition_graph(g, cfg["parts"], seed=0)

    def final_loss(recycle: int) -> tuple[float, float]:
        gnn = make_gnn("gcn", g.feature_dim, cfg["hidden"], g.num_classes,
                       cfg["layers"])
        s = ClusterSampler(g, cfg["parts"], cfg["c"], parts=pts, seed=1)
        tr = GNNTrainer(gnn, LMC, g, s, sgd(lr=cfg["lr"]), seed=0,
                        prefetch=2, recycle=recycle,
                        pipeline_mode=cfg["mode"])
        tr.run(steps)
        fl = float(full_loss(gnn, tr.params, data))
        acc = float(tr.eval("val"))
        tr.close()
        return fl, acc

    l1, a1 = final_loss(1)
    l4, a4 = final_loss(4)
    rel = abs(l4 - l1) / max(l1, 1e-9)
    gate = steps >= 1000
    row = {"loss_recycle1": l1, "loss_recycle4": l4, "rel_gap": rel,
           "val_acc_recycle1": a1, "val_acc_recycle4": a4,
           "steps": steps, "lr": cfg["lr"], "schedule": cfg["mode"],
           "gate": gate}
    print(f"pipeline/recycle_parity,{steps},"
          f"loss_r1={l1:.4f};loss_r4={l4:.4f};rel_gap={rel:.3f};"
          f"gate={gate}", flush=True)
    if gate and rel > 0.05:
        # artifacts must still be written; the assertion lives in check.sh
        print(f"# WARNING: recycle-4 loss parity {rel:.1%} exceeds the 5% "
              f"acceptance bar at {steps} steps", flush=True)
    return {"recycle_parity": row}


def bench_pipeline(fast: bool = False) -> dict:
    """Sync-vs-prefetch step times, overlap fractions and recycle parity."""
    rows = _timing_rows(fast)
    rows.update(_parity_rows(fast))
    return rows


def main() -> None:
    """Standalone entry point mirroring ``benchmarks.run``'s artifact shape."""
    import jax

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fast", action="store_true",
                    help="fewer timing steps and a short (non-gating) "
                         "parity horizon")
    args = ap.parse_args()
    OUT.mkdir(parents=True, exist_ok=True)
    print("name,us_per_call,derived")
    rows = bench_pipeline(fast=args.fast)
    artifact = {"name": "pipeline", "backend": jax.default_backend(),
                "agg_backend": "segment", "rows": rows}
    path = OUT / "BENCH_pipeline.json"
    path.write_text(json.dumps(artifact, indent=2, sort_keys=True))
    print(f"# wrote {path.relative_to(ROOT)}", flush=True)


if __name__ == "__main__":
    main()
