"""Line-coverage floor for the numerics core (`scripts/check.sh` gate).

Measures line coverage of ``src/repro/core`` + ``src/repro/kernels`` under a
targeted pytest subset (the LMC step/compensation tests, the kernel property
tests and the ELL-backend equivalence tests — the suites whose whole job is
exercising those two packages) and fails if it drops below ``FLOOR``.

Prefers coverage.py when importable.  The pinned container does not ship it,
so the fallback is self-contained stdlib machinery:

* numerator  — a ``sys.settrace``/``threading.settrace`` line tracer that
  records ``(filename, lineno)`` only for frames inside the target packages
  (every other frame pays one set lookup per call event and is not traced);
* denominator — ``compile()`` each target file and walk ``co_lines()`` over
  the full nested code-object tree (PEP 626 makes that the exact set of
  traceable lines, which is what the numerator can ever hit).

The tracer is installed *before* pytest is imported so that the one-time
module-level lines of the target packages (executed at first import, during
collection) are credited.

Run: ``PYTHONPATH=src python scripts/coverage_gate.py [extra pytest args]``.
"""
from __future__ import annotations

import sys
import types
from collections import defaultdict
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
TARGET_DIRS = (ROOT / "src" / "repro" / "core",
               ROOT / "src" / "repro" / "kernels")
TESTS = ("tests/test_lmc_core.py", "tests/test_kernels.py",
         "tests/test_ell_backend.py", "tests/test_backend_matrix.py")
FLOOR = 85.0   # measured 92.x% on the pinned container; margin for drift

TARGET_FILES = frozenset(
    str(p) for d in TARGET_DIRS for p in sorted(d.rglob("*.py")))
_executed: dict[str, set[int]] = defaultdict(set)


def _line_tracer(frame, event, arg):
    if event == "line":
        _executed[frame.f_code.co_filename].add(frame.f_lineno)
    return _line_tracer


def _call_tracer(frame, event, arg):
    if frame.f_code.co_filename in TARGET_FILES:
        return _line_tracer
    return None


def _executable_lines(path: str) -> set[int]:
    code = compile(Path(path).read_text(), path, "exec")
    lines: set[int] = set()
    stack = [code]
    while stack:
        co = stack.pop()
        lines.update(ln for *_, ln in co.co_lines() if ln is not None)
        stack.extend(c for c in co.co_consts
                     if isinstance(c, types.CodeType))
    return lines


def _run_pytest(argv: list[str]) -> int:
    import pytest
    return pytest.main(["-q", "-p", "no:cacheprovider", *TESTS, *argv])


def main(argv: list[str]) -> int:
    try:
        import coverage
    except ImportError:
        coverage = None

    if coverage is not None:
        cov = coverage.Coverage(source=[str(d) for d in TARGET_DIRS])
        cov.start()
        rc = _run_pytest(argv)
        cov.stop()
        pct = cov.report(show_missing=False)
    else:
        import threading
        threading.settrace(_call_tracer)
        sys.settrace(_call_tracer)
        rc = _run_pytest(argv)
        sys.settrace(None)
        threading.settrace(None)

        total = hit = 0
        for f in sorted(TARGET_FILES):
            ex = _executable_lines(f)
            got = _executed.get(f, set()) & ex
            total += len(ex)
            hit += len(got)
            rel = Path(f).relative_to(ROOT)
            print(f"coverage: {rel} {len(got)}/{len(ex)} "
                  f"({100 * len(got) / max(len(ex), 1):.0f}%)")
        pct = 100.0 * hit / max(total, 1)

    if rc != 0:
        print(f"coverage gate: pytest exited {rc}; not checking the floor")
        return rc
    print(f"coverage gate: repro.core+repro.kernels {pct:.1f}% "
          f"(floor {FLOOR:.0f}%)")
    if pct < FLOOR:
        print(f"coverage gate: FAILED — {pct:.1f}% < {FLOOR:.0f}%")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
