"""Line-coverage floors for the numerics core + serving tier (`check.sh`).

Measures line coverage of the load-bearing packages under a targeted pytest
subset and fails if any group drops below its floor:

* ``core+kernels`` — ``src/repro/core`` + ``src/repro/kernels`` under the
  LMC step/compensation tests, the kernel property tests and the
  ELL-backend equivalence tests;
* ``serve`` — ``src/repro/serve`` under the serving unit + fault-matrix
  suite (``tests/test_serve.py``).

Prefers coverage.py when importable.  The pinned container does not ship it,
so the fallback is self-contained stdlib machinery:

* numerator  — a ``sys.settrace``/``threading.settrace`` line tracer that
  records ``(filename, lineno)`` only for frames inside the target packages
  (every other frame pays one set lookup per call event and is not traced);
* denominator — ``compile()`` each target file and walk ``co_lines()`` over
  the full nested code-object tree (PEP 626 makes that the exact set of
  traceable lines, which is what the numerator can ever hit).

The tracer is installed *before* pytest is imported so that the one-time
module-level lines of the target packages (executed at first import, during
collection) are credited.  ``threading.settrace`` matters for the serving
group: the server's worker thread executes most of server.py.

Run: ``PYTHONPATH=src python scripts/coverage_gate.py [extra pytest args]``.
"""
from __future__ import annotations

import sys
import types
from collections import defaultdict
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
SRC = ROOT / "src" / "repro"
GROUPS = {
    "core+kernels": {"dirs": (SRC / "core", SRC / "kernels"), "floor": 85.0},
    "serve": {"dirs": (SRC / "serve",), "floor": 85.0},
}
TESTS = ("tests/test_lmc_core.py", "tests/test_kernels.py",
         "tests/test_ell_backend.py", "tests/test_backend_matrix.py",
         "tests/test_serve.py")

GROUP_FILES = {
    name: frozenset(str(p) for d in g["dirs"] for p in sorted(d.rglob("*.py")))
    for name, g in GROUPS.items()}
TARGET_FILES = frozenset().union(*GROUP_FILES.values())
_executed: dict[str, set[int]] = defaultdict(set)


def _line_tracer(frame, event, arg):
    if event == "line":
        _executed[frame.f_code.co_filename].add(frame.f_lineno)
    return _line_tracer


def _call_tracer(frame, event, arg):
    if frame.f_code.co_filename in TARGET_FILES:
        return _line_tracer
    return None


def _executable_lines(path: str) -> set[int]:
    code = compile(Path(path).read_text(), path, "exec")
    lines: set[int] = set()
    stack = [code]
    while stack:
        co = stack.pop()
        lines.update(ln for *_, ln in co.co_lines() if ln is not None)
        stack.extend(c for c in co.co_consts
                     if isinstance(c, types.CodeType))
    return lines


def _run_pytest(argv: list[str]) -> int:
    import pytest
    return pytest.main(["-q", "-p", "no:cacheprovider", *TESTS, *argv])


def main(argv: list[str]) -> int:
    try:
        import coverage
    except ImportError:
        coverage = None

    if coverage is not None:
        cov = coverage.Coverage(
            source=[str(d) for g in GROUPS.values() for d in g["dirs"]])
        cov.start()
        rc = _run_pytest(argv)
        cov.stop()

        def file_cov(f):
            _, statements, _, missing, _ = cov.analysis2(f)
            return len(statements) - len(missing), len(statements)
    else:
        import threading
        threading.settrace(_call_tracer)
        sys.settrace(_call_tracer)
        rc = _run_pytest(argv)
        sys.settrace(None)
        threading.settrace(None)

        def file_cov(f):
            ex = _executable_lines(f)
            return len(_executed.get(f, set()) & ex), len(ex)

    if rc != 0:
        print(f"coverage gate: pytest exited {rc}; not checking the floors")
        return rc

    failed = False
    for name, g in GROUPS.items():
        total = hit = 0
        for f in sorted(GROUP_FILES[name]):
            got, ex = file_cov(f)
            total += ex
            hit += got
            rel = Path(f).relative_to(ROOT)
            print(f"coverage: {rel} {got}/{ex} "
                  f"({100 * got / max(ex, 1):.0f}%)")
        pct = 100.0 * hit / max(total, 1)
        floor = g["floor"]
        print(f"coverage gate: {name} {pct:.1f}% (floor {floor:.0f}%)")
        if pct < floor:
            print(f"coverage gate: FAILED — {name} {pct:.1f}% < {floor:.0f}%")
            failed = True
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
