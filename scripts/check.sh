#!/usr/bin/env bash
# CI gate: tier-1 test suite + the kernel perf tripwire.
#   scripts/check.sh [extra pytest args...]
# The spmm benchmark writes experiments/bench/BENCH_spmm.json and asserts the
# vectorized ELL builder's >=10x speedup over the legacy loop.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

python -m pytest -x -q "$@"
python -m benchmarks.run --fast --only spmm_kernel
python - <<'EOF'
import json
rows = json.load(open("experiments/bench/BENCH_spmm.json"))["rows"]
speedup = rows["build_ell_vectorized_50k"]["speedup_vs_loop"]
assert speedup >= 10.0, f"vectorized build_ell only {speedup:.1f}x faster"
print(f"check OK: build_ell vectorized {speedup:.1f}x over the loop")
EOF
