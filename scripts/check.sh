#!/usr/bin/env bash
# CI gate: static analysis + tier-1 test suite + the kernel perf tripwires.
#   scripts/check.sh [extra pytest args...]
# Gate 1 is `python -m repro.analysis src/` (DESIGN.md §8): the
# kernel/sharding invariant rules (R001-R005) fail fast — before the test
# suite or benchmarks spend minutes — on any unsuppressed finding, printing
# the per-rule summary alongside the perf-tripwire output below.
# The spmm/compensate benchmarks rewrite experiments/bench/BENCH_{spmm,
# compensate}.json; fresh kernel-path timings are compared against the
# *committed* baselines (snapshotted before the run) and the gate fails on a
# >1.3x regression of the default (streamed) pallas kernel path, plus the
# vectorized ELL builder's >=10x speedup over the legacy loop.
# The pipeline benchmark (DESIGN.md §9) adds two more tripwires: the
# prefetch-path step must stay within 1.25x of the pure-compute step, and
# the recycled overlap fraction must not drop more than 0.25 below the
# committed baseline.
# The supervisor benchmark (DESIGN.md §10) gates the health-guard overhead:
# a guarded step must stay <= 1.10x the unguarded step median
# (BENCH_supervisor.json), and the fault-injection matrix (preemption /
# pipeline-worker crash / mid-save ckpt failure / NaN batch, each recovering
# to a stream-deterministic resume) runs in gate 1, before the full suite.
# The backends benchmark (DESIGN.md §11) races segment/ell/ti through one
# sampler stream and gates the store-free ti estimator: step time <= ell
# (strict on compiled backends, jitter headroom under the CPU interpreter),
# zero store bytes/step, and terminal-loss parity on full-fidelity runs.
# The serve benchmark (DESIGN.md §12) gates the serving tier: clean p99
# latency <= 1.3x the committed baseline at the fixed gated QPS level,
# degraded-rung (ti) val-accuracy within 0.05 of the exact rung, and zero
# dropped in-flight requests on drain; the serving fault matrix (hung
# batch / poisoned store rows / queue-overflow burst / worker crash) runs
# in gate 1b alongside the training matrix.
# scripts/coverage_gate.py enforces line-coverage floors over
# repro.core+repro.kernels and repro.serve before the benchmarks run.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

python -m repro.analysis src/

# gate 1b: the fault-injection matrix fails fast — a broken recovery path
# invalidates every longer-running gate below it
python -m pytest -q tests/test_supervisor.py tests/test_serve.py -k "matrix"

# docstring hygiene (ruff D rules scoped in ruff.toml); optional: the pinned
# container may not ship ruff, and the bespoke `repro.analysis` pass above is
# the authoritative gate
if command -v ruff >/dev/null 2>&1; then
    ruff check .
else
    echo "check: ruff not installed; skipping lint (config in ruff.toml)"
fi

python -m pytest -x -q "$@"

# coverage floor (DESIGN.md §11): line coverage of repro.core+repro.kernels
# under the targeted numerics suites must stay >= the floor in
# scripts/coverage_gate.py (stdlib settrace tracer — the container has no
# coverage.py; the script upgrades itself automatically when one appears)
python scripts/coverage_gate.py

# snapshot the *committed* baselines (HEAD, not the working tree — the
# benches below rewrite the working-tree files, and ratcheting against the
# previous run would let a slow <1.3x-per-run regression through)
BASE_DIR=$(mktemp -d)
trap 'rm -rf "$BASE_DIR"' EXIT
for f in experiments/bench/BENCH_spmm.json experiments/bench/BENCH_compensate.json \
         experiments/bench/BENCH_pipeline.json experiments/bench/BENCH_backends.json \
         experiments/bench/BENCH_serve.json; do
    git show "HEAD:$f" > "$BASE_DIR/$(basename "$f")" 2>/dev/null \
        || rm -f "$BASE_DIR/$(basename "$f")"   # not committed yet: no gate
done

python -m benchmarks.run --fast --only spmm_kernel
python -m benchmarks.run --fast --only compensate
python -m benchmarks.run --fast --only pipeline
python -m benchmarks.run --fast --only supervisor
python -m benchmarks.run --fast --only backends
python -m benchmarks.run --fast --only serve

BASELINE_DIR="$BASE_DIR" python - <<'EOF'
import json
import os
from pathlib import Path

TOL = 1.3   # fail on >1.3x slowdown of any kernel-path row
base_dir = Path(os.environ["BASELINE_DIR"])

rows = json.load(open("experiments/bench/BENCH_spmm.json"))["rows"]
speedup = rows["build_ell_vectorized_50k"]["speedup_vs_loop"]
assert speedup >= 10.0, f"vectorized build_ell only {speedup:.1f}x faster"
print(f"check OK: build_ell vectorized {speedup:.1f}x over the loop")

for name in ("BENCH_spmm.json", "BENCH_compensate.json"):
    bpath = base_dir / name
    if not bpath.exists():
        print(f"check: no committed baseline for {name}; skipping tripwire")
        continue
    base = json.load(open(bpath))
    fresh = json.load(open(f"experiments/bench/{name}"))
    if base.get("backend") != fresh.get("backend"):
        # interpret-vs-compiled timings are not comparable across machines
        print(f"check: {name} baseline backend {base.get('backend')!r} != "
              f"{fresh.get('backend')!r}; skipping tripwire")
        continue
    for key, row in fresh["rows"].items():
        # gate the production kernel path (default_path rows); the legacy
        # resident-block comparison rows are informational and too jittery
        # under the interpreter to gate on
        if not key.startswith("pallas_") or not row.get("default_path"):
            continue
        old = base["rows"].get(key)
        if old is None or "us_per_call" not in row:
            continue
        ratio = row["us_per_call"] / max(old["us_per_call"], 1e-9)
        assert ratio <= TOL, (
            f"{name}:{key} regressed {ratio:.2f}x "
            f"({old['us_per_call']:.0f}us -> {row['us_per_call']:.0f}us)")
        print(f"check OK: {name}:{key} {ratio:.2f}x vs baseline")

# pipeline tripwires (DESIGN.md §9): absolute prefetch-overhead bound plus
# an overlap-fraction regression gate against the committed baseline
PIPE_RATIO_TOL = 1.25    # fast-mode headroom over the 1.15x acceptance bar
OVERLAP_DROP_TOL = 0.25  # absolute drop allowed in the recycled overlap
fresh = json.load(open("experiments/bench/BENCH_pipeline.json"))["rows"]
pr = fresh["step_prefetch"]["ratio_vs_compute"]
assert pr <= PIPE_RATIO_TOL, (
    f"pipeline:step_prefetch costs {pr:.2f}x the pure-compute step "
    f"(bound {PIPE_RATIO_TOL}x)")
print(f"check OK: pipeline:step_prefetch {pr:.2f}x vs pure compute")
par = fresh["recycle_parity"]
if par.get("gate"):
    assert par["rel_gap"] <= 0.05, (
        f"pipeline:recycle_parity gap {par['rel_gap']:.1%} > 5% "
        f"at {par['steps']} steps")
    print(f"check OK: pipeline:recycle_parity {par['rel_gap']:.1%}")
bpath = base_dir / "BENCH_pipeline.json"
if bpath.exists():
    old = json.load(open(bpath))["rows"]["overlap"]["overlap_fraction_recycle4"]
    new = fresh["overlap"]["overlap_fraction_recycle4"]
    assert new >= old - OVERLAP_DROP_TOL, (
        f"pipeline:overlap_fraction_recycle4 dropped {old:.2f} -> {new:.2f} "
        f"(> {OVERLAP_DROP_TOL} below the committed baseline)")
    print(f"check OK: pipeline:overlap_fraction_recycle4 {new:.2f} "
          f"(baseline {old:.2f})")
else:
    print("check: no committed baseline for BENCH_pipeline.json; "
          "skipping overlap tripwire")

# supervisor tripwire (DESIGN.md §10): the numerical-health guard must stay
# essentially free — its inputs are host floats the step already syncs for
# the history record, so > 1.10x means someone put work on the hot path
GUARD_RATIO_TOL = 1.10
sup = json.load(open("experiments/bench/BENCH_supervisor.json"))["rows"]
gr = sup["step_guarded"]["ratio_vs_unguarded"]
assert gr <= GUARD_RATIO_TOL, (
    f"supervisor:step_guarded costs {gr:.2f}x the unguarded step "
    f"(bound {GUARD_RATIO_TOL}x)")
print(f"check OK: supervisor:step_guarded {gr:.2f}x vs unguarded")
sp = sup["ckpt_async_save"]["async_speedup"]
assert sp >= 1.0, (
    f"supervisor:ckpt_async_save is {sp:.2f}x sync — background saves "
    f"should never cost the training thread more than synchronous ones")
print(f"check OK: supervisor:ckpt_async_save {sp:.1f}x cheaper on the "
      f"hot path")

# backend tripwires (DESIGN.md §11): ti removes every historical-store
# read/write from the step, so it must never cost more than ell.  On a
# compiled backend that bound is strict (1.0x); under the CPU interpreter
# the Pallas SpMM dominates and single-epoch jitter (~±15%) swamps the
# compensate traffic ti saves, so the CPU gate carries jitter headroom —
# it still trips if ti systematically does *more* work than ell.
bb = json.load(open("experiments/bench/BENCH_backends.json"))
TI_RATIO_TOL = 1.0 if bb.get("backend") != "cpu" else 1.15
tv = bb["rows"]["ti_vs_ell"]
assert tv["step_ratio"] <= TI_RATIO_TOL, (
    f"backends:ti step costs {tv['step_ratio']:.2f}x the ell step "
    f"(bound {TI_RATIO_TOL}x on backend {bb.get('backend')!r})")
print(f"check OK: backends:ti {tv['step_ratio']:.2f}x vs ell "
      f"(bound {TI_RATIO_TOL}x)")
for k in ("store_read_bytes_per_step", "store_write_bytes_per_step"):
    assert bb["rows"]["ti"][k] == 0, f"backends:ti nonzero {k}"
print("check OK: backends:ti store traffic 0+0 bytes/step")
if tv.get("gate"):
    assert tv["loss_rel_gap"] <= 0.05, (
        f"backends:ti terminal loss diverges {tv['loss_rel_gap']:.1%} "
        f"from ell at {tv['steps']} steps")
    print(f"check OK: backends:ti_vs_ell loss gap {tv['loss_rel_gap']:.1%}")

# serving tripwires (DESIGN.md §12): p99 regression at the gated QPS level,
# degraded-rung answer quality, and drain accounting
SERVE_P99_TOL = 1.3      # same budget as the kernel-path tripwires
SERVE_PARITY_TOL = 0.05  # ti val-accuracy may trail exact by at most this
sv = json.load(open("experiments/bench/BENCH_serve.json"))
srows = sv["rows"]
gated = [k for k, r in srows.items()
         if k.endswith("_clean") and r.get("default_path")]
bpath = base_dir / "BENCH_serve.json"
if not bpath.exists():
    print("check: no committed baseline for BENCH_serve.json; "
          "skipping p99 tripwire")
else:
    base = json.load(open(bpath))
    if base.get("backend") != sv.get("backend"):
        print(f"check: BENCH_serve.json baseline backend "
              f"{base.get('backend')!r} != {sv.get('backend')!r}; "
              f"skipping p99 tripwire")
    else:
        for key in gated:
            old = base["rows"].get(key)
            if old is None or "p99_us" not in old:
                continue
            ratio = srows[key]["p99_us"] / max(old["p99_us"], 1e-9)
            assert ratio <= SERVE_P99_TOL, (
                f"serve:{key} p99 regressed {ratio:.2f}x "
                f"({old['p99_us']:.0f}us -> {srows[key]['p99_us']:.0f}us)")
            print(f"check OK: serve:{key} p99 {ratio:.2f}x vs baseline")
par = srows["parity_ti"]
assert par["val_acc_gap"] <= SERVE_PARITY_TOL, (
    f"serve:parity_ti degraded rung trails exact by "
    f"{par['val_acc_gap']:.3f} val accuracy (bound {SERVE_PARITY_TOL})")
print(f"check OK: serve:parity_ti acc gap {par['val_acc_gap']:.3f} "
      f"(agreement {par['top1_agreement']:.1%})")
dr = srows["drain"]
assert dr["dropped"] == 0 and dr["clean_exit"], (
    f"serve:drain dropped {dr['dropped']} of {dr['submitted']} in-flight "
    f"requests (clean_exit={dr['clean_exit']})")
print(f"check OK: serve:drain {dr['resolved_ok']}/{dr['submitted']} "
      f"resolved, 0 dropped")
EOF
